"""OpenAI-style HTTP completions server over ``AsyncLLM`` — stdlib only.

POST /v1/completions with a JSON body::

    {"prompt": [3, 14, 15, 9], "max_tokens": 16, "temperature": 0.0,
     "stream": false, "priority": 0, "timeout_s": 0}

``prompt`` is a list of token ids (this repo ships no tokenizer; the
demo detokenizer renders ids as space-joined integers). Non-streaming
requests get one JSON object; ``"stream": true`` gets Server-Sent
Events (``data: {...}\\n\\n`` per chunk, ``data: [DONE]`` at the end),
each chunk carrying the tokens that step produced. GET /v1/stats
returns engine counters (steps, preemptions, pool occupancy).

Observability routes (live when ``EngineConfig.telemetry`` != "off";
404 otherwise):

* ``GET /v1/metrics`` — Prometheus text exposition (format 0.0.4) of
  the engine's metrics registry, driver restarts folded in.
* ``GET /v1/requests/<uid>/timeline`` — one request's lifecycle
  timeline (enqueue/admit/phase/first_token/finish events + derived
  TTFT/queue/ITL summary) as JSON; completions responses carry the
  ``uid`` to query.

The serving tier's typed failure taxonomy maps onto HTTP status codes:

=====  =====================================================
400    ``ValidationError`` / malformed body — the request
       itself is wrong (never admitted, nothing to clean up)
408    per-request wall-clock ``timeout_s`` expired — the
       request is ABORTED engine-side (pages returned) and
       the partial tokens are returned with
       ``finish_reason="timeout"``
429    ``CapacityError`` — the request can never fit the
       page pool; retry smaller or elsewhere
500    quarantine (``finish_reason="error"`` terminal chunk
       or a raised ``QuarantineError``) — ONE request was
       typed-failed mid-flight; the batch keeps serving
503    ``EngineFault`` / dead driver — the engine itself is
       suspect; every stream gets this until restart
=====  =====================================================

Because the server rides ``AsyncLLM``, every connection shares ONE
continuous batch: concurrent requests are co-scheduled by the engine's
SLO knobs (chunked prefill bounds ITL stalls; ``priority`` classes
preempt under page pressure).

Run (serves until Ctrl-C)::

    python examples/serve_http.py --port 8080

Self-test (starts the server in-process, runs a scripted client,
exits)::

    python examples/serve_http.py --selftest
"""
import argparse
import asyncio
import json
import sys
import time

import numpy as np

import jax

from repro.configs.base import get_config, reduced
from repro.models import transformer as tfm
from repro.serving.async_api import AsyncLLM
from repro.serving.engine import EngineConfig
from repro.serving.faults import (CapacityError, EngineFault, RequestError,
                                  ValidationError)
from repro.serving.sampling import FINISH_ERROR, SamplingParams


def build_llm(arch: str = "chai-llama-7b", *, faults=None,
              telemetry: str = "basic") -> AsyncLLM:
    """A tiny demo model (random weights) behind a full serving stack.

    ``num_pages`` is deliberately smaller than the auto worst case so an
    oversized (but max_seq-legal) request hits the page-budget
    ``CapacityError`` -> 429 path instead of being admissible always."""
    cfg = reduced(get_config(arch), n_layers=2, d_model=64, d_ff=128,
                  vocab=256).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True, warmup_tokens=8)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(batch_slots=4, max_seq=256, page_size=16,
                        prefix_cache=True, prefill_chunk_tokens=32,
                        telemetry=telemetry,
                        num_pages=17)       # 16 usable = 128 tokens/req
    detok = lambda ids: " ".join(map(str, ids))
    return AsyncLLM(cfg, params, ecfg, detokenizer=detok, faults=faults)


def _code_of(err: BaseException) -> int:
    """Typed failure taxonomy -> HTTP status (see module docstring)."""
    if isinstance(err, CapacityError):
        return 429
    if isinstance(err, (ValidationError, ValueError, KeyError, TypeError)):
        return 400
    if isinstance(err, RequestError):
        return 500                          # quarantined mid-flight
    if isinstance(err, (EngineFault, RuntimeError)):
        return 503                          # engine/driver is suspect
    return 500


def _params_of(body: dict) -> SamplingParams:
    return SamplingParams(
        max_new_tokens=int(body.get("max_tokens", 16)),
        temperature=float(body.get("temperature", 0.0)),
        top_k=int(body.get("top_k", 0)),
        top_p=float(body.get("top_p", 1.0)),
        seed=int(body.get("seed", 0)))


async def _read_request(reader) -> tuple:
    """Minimal HTTP/1.1 parse: (method, path, body-bytes)."""
    line = await reader.readline()
    if not line:
        return None, None, b""
    method, path, _ = line.decode("latin1").split(" ", 2)
    length = 0
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        name, _, val = h.decode("latin1").partition(":")
        if name.strip().lower() == "content-length":
            length = int(val.strip())
    body = await reader.readexactly(length) if length else b""
    return method, path, body


def _response(code: int, payload: bytes, ctype: str = "application/json",
              extra: str = "") -> bytes:
    reason = {200: "OK", 400: "Bad Request", 404: "Not Found",
              408: "Request Timeout", 429: "Too Many Requests",
              500: "Internal Server Error", 503: "Service Unavailable"}[code]
    return (f"HTTP/1.1 {code} {reason}\r\nContent-Type: {ctype}\r\n"
            f"Content-Length: {len(payload)}\r\nConnection: close\r\n"
            f"{extra}\r\n").encode("latin1") + payload


class Server:
    def __init__(self, llm: AsyncLLM):
        self.llm = llm

    async def handle(self, reader, writer):
        try:
            method, path, raw = await _read_request(reader)
            if method is None:
                return
            if method == "GET" and path == "/v1/stats":
                await self._stats(writer)
            elif method == "GET" and path == "/v1/metrics":
                await self._metrics(writer)
            elif (method == "GET" and path.startswith("/v1/requests/")
                    and path.endswith("/timeline")):
                await self._timeline(writer, path)
            elif method == "POST" and path == "/v1/completions":
                await self._completions(writer, raw)
            else:
                writer.write(_response(404, b'{"error": "not found"}'))
        except (asyncio.IncompleteReadError, ConnectionResetError):
            pass
        except Exception as err:  # noqa: BLE001 — report, keep serving
            msg = json.dumps({"error": str(err),
                              "type": type(err).__name__}).encode()
            try:
                writer.write(_response(_code_of(err), msg))
            except Exception:   # noqa: BLE001
                pass
        finally:
            try:
                await writer.drain()
                writer.close()
            except Exception:   # noqa: BLE001
                pass

    async def _stats(self, writer):
        core = self.llm.core
        stats = {"steps": core.steps_executed,
                 "preemptions": core.preemptions,
                 "cluster_transitions": core.cluster_transitions,
                 "dense_pages_in_use": core.dense_pool.pages_in_use,
                 "prefix_cache": core.prefix_stats()}
        writer.write(_response(200, json.dumps(stats).encode()))

    async def _metrics(self, writer):
        """Prometheus text exposition; 404 when telemetry is off."""
        from repro.serving.exporters import PROMETHEUS_CONTENT_TYPE
        text = await self.llm.metrics_text()
        if text is None:
            writer.write(_response(404, b'{"error": "telemetry is off"}'))
            return
        writer.write(_response(200, text.encode(),
                               ctype=PROMETHEUS_CONTENT_TYPE))

    async def _timeline(self, writer, path: str):
        """GET /v1/requests/<uid>/timeline -> lifecycle event JSON."""
        try:
            uid = int(path.split("/")[3])
        except (IndexError, ValueError):
            raise ValidationError(f"bad timeline path {path!r}")
        tl = await self.llm.timeline(uid)
        if tl is None:
            writer.write(_response(
                404, json.dumps({"error": f"no timeline for uid {uid} "
                                          "(unknown uid or telemetry "
                                          "off)"}).encode()))
            return
        writer.write(_response(200, json.dumps(tl).encode()))

    async def _completions(self, writer, raw: bytes):
        body = json.loads(raw or b"{}")
        if "prompt" not in body:
            raise ValidationError("body is missing 'prompt'")
        prompt = np.asarray(body["prompt"], np.int32)
        sp = _params_of(body)
        priority = int(body.get("priority", 0))
        timeout_s = float(body.get("timeout_s", 0) or 0)
        if body.get("stream"):
            head = ("HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n"
                    "Cache-Control: no-cache\r\nConnection: close\r\n\r\n")
            writer.write(head.encode("latin1"))
            await writer.drain()
            async for chunk in self.llm.stream(prompt, sp,
                                               priority=priority):
                data = {"uid": chunk.uid,
                        "tokens": chunk.token_ids,
                        "finished": chunk.finished,
                        "finish_reason": chunk.finish_reason or None}
                writer.write(f"data: {json.dumps(data)}\n\n".encode())
                await writer.drain()
            writer.write(b"data: [DONE]\n\n")
            return
        tokens, finish, timed_out, uid = await self._collect(
            prompt, sp, priority, timeout_s)
        if timed_out:
            payload = {"uid": uid, "tokens": tokens,
                       "finish_reason": "timeout",
                       "error": f"request exceeded timeout_s={timeout_s}"}
            writer.write(_response(408, json.dumps(payload).encode()))
            return
        code = 500 if finish == FINISH_ERROR else 200
        payload = {"uid": uid, "tokens": tokens, "finish_reason": finish}
        if code == 200:
            payload["text"] = self.llm.core.detokenizer(tokens) \
                if self.llm.core.detokenizer else ""
        writer.write(_response(code, json.dumps(payload).encode()))

    async def _collect(self, prompt, sp, priority, timeout_s):
        """Drain one request's stream under an optional wall-clock
        deadline. On expiry the stream generator is closed, which aborts
        the request ENGINE-side (its pages return refcount-exactly) —
        the partial tokens are still returned to the client."""
        tokens, finish, uid = [], None, None
        deadline = time.monotonic() + timeout_s if timeout_s > 0 else None
        agen = self.llm.stream(prompt, sp, priority=priority)
        try:
            while True:
                if deadline is None:
                    chunk = await agen.__anext__()
                else:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        return tokens, finish, True, uid
                    try:
                        chunk = await asyncio.wait_for(agen.__anext__(),
                                                       left)
                    except asyncio.TimeoutError:
                        return tokens, finish, True, uid
                uid = chunk.uid
                tokens.extend(chunk.token_ids)
                finish = chunk.finish_reason
                if chunk.finished:
                    return tokens, finish, False, uid
        except StopAsyncIteration:          # defensive: stream drained
            return tokens, finish, False, uid
        finally:
            await agen.aclose()             # no-op if already finished


async def serve(host: str, port: int, llm=None, ready=None):
    llm = llm or build_llm()
    async with llm:
        server = await asyncio.start_server(Server(llm).handle, host, port)
        addr = server.sockets[0].getsockname()
        print(f"serving on http://{addr[0]}:{addr[1]}  "
              f"(POST /v1/completions, GET /v1/stats, /v1/metrics, "
              f"/v1/requests/<uid>/timeline)")
        if ready is not None:
            ready.set_result(addr)
        async with server:
            await server.serve_forever()


async def _get(host, port, path) -> tuple:
    """GET ``path``; returns (status_code, content_type, raw body)."""
    reader, writer = await asyncio.open_connection(host, port)
    writer.write((f"GET {path} HTTP/1.1\r\nHost: {host}\r\n\r\n"
                  ).encode("latin1"))
    await writer.drain()
    data = await reader.read()
    writer.close()
    head, _, tail = data.partition(b"\r\n\r\n")
    code = int(head.split(b" ", 2)[1])
    ctype = ""
    for ln in head.split(b"\r\n"):
        if ln.lower().startswith(b"content-type:"):
            ctype = ln.partition(b":")[2].strip().decode("latin1")
    return code, ctype, tail


async def _client(host, port, body) -> tuple:
    """POST /v1/completions; returns (status_code, parsed body)."""
    reader, writer = await asyncio.open_connection(host, port)
    payload = json.dumps(body).encode()
    writer.write((f"POST /v1/completions HTTP/1.1\r\nHost: {host}\r\n"
                  f"Content-Type: application/json\r\n"
                  f"Content-Length: {len(payload)}\r\n\r\n"
                  ).encode() + payload)
    await writer.drain()
    data = await reader.read()
    writer.close()
    head, _, tail = data.partition(b"\r\n\r\n")
    code = int(head.split(b" ", 2)[1])
    if b"text/event-stream" in head:
        chunks = [json.loads(ln[6:]) for ln in tail.split(b"\n")
                  if ln.startswith(b"data: ") and b"[DONE]" not in ln]
        return code, {"stream": chunks}
    return code, json.loads(tail)


async def selftest(port: int = 8181):
    loop = asyncio.get_running_loop()
    ready = loop.create_future()
    task = loop.create_task(serve("127.0.0.1", port, ready=ready))
    await ready
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 256, size=24).tolist()
    code, out = await _client("127.0.0.1", port,
                              {"prompt": prompt, "max_tokens": 8})
    assert code == 200 and len(out["tokens"]) == 8, (code, out)
    code, srm = await _client("127.0.0.1", port,
                              {"prompt": prompt, "max_tokens": 8,
                               "stream": True})
    got = [t for c in srm["stream"] for t in c["tokens"]]
    assert code == 200 and got == out["tokens"], (got, out)
    both = await asyncio.gather(
        _client("127.0.0.1", port, {"prompt": prompt, "max_tokens": 8}),
        _client("127.0.0.1", port,
                {"prompt": rng.integers(0, 256, size=16).tolist(),
                 "max_tokens": 8, "priority": 1}))
    assert both[0][0] == 200 and both[0][1]["tokens"] == out["tokens"]

    # -- typed failures -> HTTP codes -----------------------------------
    # 400: malformed (no prompt) and ValidationError (exceeds max_seq)
    code, body = await _client("127.0.0.1", port, {"max_tokens": 4})
    assert code == 400, (code, body)
    code, body = await _client("127.0.0.1", port,
                               {"prompt": prompt, "max_tokens": 300})
    assert code == 400 and body["type"] == "ValidationError", (code, body)
    # 429: legal length but can never fit the (deliberately small) pool
    code, body = await _client(
        "127.0.0.1", port,
        {"prompt": rng.integers(0, 256, size=150).tolist(),
         "max_tokens": 8})
    assert code == 429 and body["type"] == "CapacityError", (code, body)
    # 408: wall-clock timeout aborts engine-side, returns partial tokens
    code, body = await _client("127.0.0.1", port,
                               {"prompt": prompt, "max_tokens": 64,
                                "timeout_s": 0.15})
    assert code == 408 and body["finish_reason"] == "timeout", (code, body)
    assert len(body["tokens"]) < 64, body
    # the engine kept serving through all of the above
    code, out2 = await _client("127.0.0.1", port,
                               {"prompt": prompt, "max_tokens": 8})
    assert code == 200 and out2["tokens"] == out["tokens"], (code, out2)
    print("selftest OK:", out["tokens"])

    # -- observability: /v1/metrics + per-request timelines -------------
    from repro.serving import exporters
    code, ctype, body = await _get("127.0.0.1", port, "/v1/metrics")
    assert code == 200, (code, body)
    assert ctype == exporters.PROMETHEUS_CONTENT_TYPE, ctype
    parsed = exporters.parse_prometheus(body.decode())
    names = {s[0] for s in parsed["samples"]}
    for want in ("requests_finished_total", "engine_steps_total",
                 "tokens_generated_total", "request_ttft_seconds_count"):
        assert want in names, (want, sorted(names))
    done = sum(v for n, _, v in parsed["samples"]
               if n == "requests_finished_total")
    assert done >= 6, parsed["samples"]
    code, _, body = await _get(
        "127.0.0.1", port, f"/v1/requests/{out2['uid']}/timeline")
    assert code == 200, (code, body)
    tl = json.loads(body)
    ev_names = [e["ev"] for e in tl["events"]]
    assert "enqueue" in ev_names and "finish" in ev_names, ev_names
    assert tl["summary"]["n_tokens"] == 8, tl["summary"]
    assert tl["summary"]["ttft_s"] is not None, tl["summary"]
    code, _, _ = await _get("127.0.0.1", port,
                            "/v1/requests/999999/timeline")
    assert code == 404, code
    print("observability selftest OK "
          f"({len(parsed['samples'])} metric samples)")
    task.cancel()

    # -- quarantine (500) and dead driver (503) on a faulted instance ---
    # (telemetry off: also covers the observability routes' 404 tier)
    from repro.serving.faults import FaultInjector, FaultSpec
    llm2 = build_llm(faults=FaultInjector(
        [FaultSpec("step.logits", mode="nan", count=1)]),
        telemetry="off")
    ready2 = loop.create_future()
    task2 = loop.create_task(
        serve("127.0.0.1", port + 1, llm=llm2, ready=ready2))
    await ready2
    code, body = await _client("127.0.0.1", port + 1,
                               {"prompt": prompt, "max_tokens": 8})
    assert code == 500 and body["finish_reason"] == "error", (code, body)
    code, _, body = await _get("127.0.0.1", port + 1, "/v1/metrics")
    assert code == 404, (code, body)       # telemetry off on this server
    code, _, body = await _get("127.0.0.1", port + 1,
                               "/v1/requests/0/timeline")
    assert code == 404, (code, body)

    def _dead_step():
        raise RuntimeError("injected persistent engine failure")
    llm2.core.step = _dead_step
    code, body = await _client("127.0.0.1", port + 1,
                               {"prompt": prompt, "max_tokens": 8})
    assert code == 503, (code, body)
    if llm2._driver is not None and llm2._driver.done():
        llm2._driver.exception()        # retrieve: silence the task log
    print("failure-model selftest OK (400/408/429/500/503)")
    task2.cancel()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8080)
    ap.add_argument("--selftest", action="store_true",
                    help="start the server in-process, run a scripted "
                         "client, exit")
    args = ap.parse_args(argv)
    if args.selftest:
        asyncio.run(selftest(args.port))
    else:
        try:
            asyncio.run(serve(args.host, args.port))
        except KeyboardInterrupt:
            pass


if __name__ == "__main__":
    main(sys.argv[1:])
