"""Pallas TPU kernels for Clustered Head Attention (the paper's core op).

Decomposition (DESIGN.md §3.2):
  1. ``chai_qk``      — raw scores for the R representative heads only
                        (R <= H: the compute CHAI removes). GQA: rep j reads
                        the K tile of its group j // reps_per_group via a
                        static index_map; MHA reads the clustered K cache.
  2. ``row_softmax``  — masked softmax over each (b, rep) row (row fits
                        VMEM; one pass).
  3. ``chai_av``      — the broadcast-and-accumulate: head h gathers the A
                        tile of its cluster via a **scalar-prefetched**
                        ``h2c`` index map (TPU-idiomatic dynamic gather, as
                        in paged-attention kernels) and multiplies with its
                        own V tile. Per-head V is preserved (Table 4).

The three-kernel split above survives only as the *oracle* (see
``repro.kernels.ref``): the production decode path is the **one-pass fused
kernel** below (``chai_fused_decode`` / ``paged_chai_fused_decode``). The
old "why not fused" argument (the rep row max/denominator is only known
after the last S tile) is answered the same way flash decode answers it:
carry online-softmax state — running max ``m`` and normalizer ``l`` per
rep row, plus per-member-head output accumulators — in VMEM scratch across
the sequentially-iterated S-tile grid axis, rescaling the accumulators by
``exp(m_prev - m_new)`` at every tile. One launch per decode step; no
``(B, R, S)`` logits ever touch HBM.

Fused dataflow per (batch, S-tile) grid step:

  K tile (R rep rows)  --QK+mask-->  scores (R, Ts)   [int8: dequant here]
  scores --online softmax update-->  m, l (R,)  +  p = exp(sc - m) (R, Ts)
  p --h2c one-hot broadcast------->  p_full (H, Ts)
  V tile (H rows)  --AV----------->  acc (H, hd) accumulators
                                     [share_values: acc stays (R, hd) and
                                      the h2c gather moves to finalize]

Paged variants (``paged_chai_fused_decode``): K/V live in page pools
addressed through scalar-prefetched int32 block tables (one S-tile == one
page) driving the BlockSpec index maps, so the serving engine's clustered
pages stream HBM->VMEM straight from the ``PagePool`` layout without
densification. int8 pools dequantize in-VMEM from the mirror-shaped scale
pools — the HBM byte saving happens on the stream, where it counts.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _interpret_default():
    return jax.default_backend() == "cpu"


# ------------------------------------------------------------------ QK ----
def _qk_kernel(pos_ref, q_ref, k_ref, o_ref, *, scale, ts, window):
    b = pl.program_id(0)
    s = pl.program_id(2)
    q = q_ref[0, 0, :].astype(jnp.float32)[None, :]        # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (Ts, hd)
    sc = jnp.dot(k, q.T, preferred_element_type=jnp.float32) * scale
    idx = s * ts + jax.lax.broadcasted_iota(jnp.int32, (ts, 1), 0)
    pos = pos_ref[b]
    valid = idx <= pos
    if window:
        valid &= (pos - idx) < window
    sc = jnp.where(valid, sc, NEG_INF)
    o_ref[0, 0, :] = sc[:, 0]


def chai_qk(q_rep, k_cache, pos, *, reps_per_group=1, window=0, ts=512,
            interpret=None):
    """q_rep: (B, R, hd); k_cache: (B, KV, S, hd) with KV*reps_per_group==R
    (MHA clustered cache: KV==R, reps_per_group==1). -> raw scores (B,R,S)."""
    if interpret is None:
        interpret = _interpret_default()
    b, r_total, hd = q_rep.shape
    s = k_cache.shape[2]
    ts = min(ts, s)
    assert s % ts == 0
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_qk_kernel, scale=scale, ts=ts, window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, r_total, s // ts),
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda bb, rr, ss, pos_r:
                             (bb, rr, 0)),
                pl.BlockSpec((1, 1, ts, hd), lambda bb, rr, ss, pos_r:
                             (bb, rr // reps_per_group, ss, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, ts), lambda bb, rr, ss, pos_r:
                                   (bb, rr, ss)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, r_total, s), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.int32), q_rep, k_cache)


# ------------------------------------------------------------- softmax ----
def _softmax_kernel(x_ref, o_ref):
    x = x_ref[0, 0, :]
    m = jnp.maximum(jnp.max(x), -1e30)
    p = jnp.exp(x - m)
    o_ref[0, 0, :] = p / jnp.maximum(jnp.sum(p), 1e-37)


def row_softmax(scores, *, interpret=None):
    """scores: (B, R, S) raw (already masked) -> normalized A (B, R, S)."""
    if interpret is None:
        interpret = _interpret_default()
    b, r, s = scores.shape
    return pl.pallas_call(
        _softmax_kernel,
        grid=(b, r),
        in_specs=[pl.BlockSpec((1, 1, s), lambda bb, rr: (bb, rr, 0))],
        out_specs=pl.BlockSpec((1, 1, s), lambda bb, rr: (bb, rr, 0)),
        out_shape=jax.ShapeDtypeStruct((b, r, s), jnp.float32),
        interpret=interpret,
    )(scores)


# ------------------------------------------------------- paged QK ---------
def _paged_qk_kernel(pos_ref, bt_ref, q_ref, k_ref, o_ref, *, scale, page,
                     window):
    b = pl.program_id(0)
    s = pl.program_id(2)               # logical page index
    q = q_ref[0, 0, :].astype(jnp.float32)[None, :]        # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (page, hd)
    sc = jnp.dot(k, q.T, preferred_element_type=jnp.float32) * scale
    idx = s * page + jax.lax.broadcasted_iota(jnp.int32, (page, 1), 0)
    pos = pos_ref[b]
    valid = idx <= pos
    if window:
        valid &= (pos - idx) < window
    o_ref[0, 0, :] = jnp.where(valid, sc, NEG_INF)[:, 0]


def paged_chai_qk(q_rep, k_pool, bt, pos, *, reps_per_group=1, window=0,
                  interpret=None):
    """Paged clustered scores. q_rep: (B, R, hd); k_pool: (nP, KV, page,
    hd) page pool with KV * reps_per_group == R (MHA clustered pool:
    KV == k_max, reps_per_group == 1); bt: (B, P) int32 block table;
    pos: (B,). Returns raw scores (B, R, P*page) — feed ``row_softmax``."""
    if interpret is None:
        interpret = _interpret_default()
    b, r_total, hd = q_rep.shape
    page = k_pool.shape[2]
    n_pages = bt.shape[1]
    s = n_pages * page
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_paged_qk_kernel, scale=scale, page=page,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, r_total, n_pages),
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda bb, rr, ss, pos_r, bt_r:
                             (bb, rr, 0)),
                pl.BlockSpec((1, 1, page, hd),
                             lambda bb, rr, ss, pos_r, bt_r:
                             (bt_r[bb, ss], rr // reps_per_group, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, page),
                                   lambda bb, rr, ss, pos_r, bt_r:
                                   (bb, rr, ss)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, r_total, s), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.int32), bt.astype(jnp.int32), q_rep, k_pool)


# ------------------------------------------------------- int8 QK ----------
def _qk_i8_kernel(pos_ref, q_ref, k_ref, ks_ref, o_ref, *, scale, ts,
                  window):
    """Fused int8-dequant scores: K tile loads 1 byte/elem from HBM and
    dequantizes in VMEM (the memory-bound decode's byte saving happens on
    the HBM->VMEM stream, which is exactly what BlockSpec tiles)."""
    b = pl.program_id(0)
    s = pl.program_id(2)
    q = q_ref[0, 0, :].astype(jnp.float32)[None, :]        # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                    # (Ts, hd) int8
    krow = ks_ref[0, 0].astype(jnp.float32)[:, None]       # (Ts, 1) scales
    sc = jnp.dot(k, q.T, preferred_element_type=jnp.float32)
    sc = sc * krow * scale
    idx = s * ts + jax.lax.broadcasted_iota(jnp.int32, (ts, 1), 0)
    pos = pos_ref[b]
    valid = idx <= pos
    if window:
        valid &= (pos - idx) < window
    o_ref[0, 0, :] = jnp.where(valid, sc, NEG_INF)[:, 0]


def chai_qk_i8(q_rep, k_cache_i8, k_scale, pos, *, reps_per_group=1,
               window=0, ts=512, interpret=None):
    """int8 variant of ``chai_qk``. k_cache_i8: (B, KV, S, hd) int8;
    k_scale: (B, KV, S) f32 per-row scales. Returns raw scores (B, R, S).
    """
    if interpret is None:
        interpret = _interpret_default()
    b, r_total, hd = q_rep.shape
    s = k_cache_i8.shape[2]
    ts = min(ts, s)
    assert s % ts == 0
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_qk_i8_kernel, scale=scale, ts=ts,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, r_total, s // ts),
            in_specs=[
                pl.BlockSpec((1, 1, hd), lambda bb, rr, ss, pos_r:
                             (bb, rr, 0)),
                pl.BlockSpec((1, 1, ts, hd), lambda bb, rr, ss, pos_r:
                             (bb, rr // reps_per_group, ss, 0)),
                pl.BlockSpec((1, 1, ts), lambda bb, rr, ss, pos_r:
                             (bb, rr // reps_per_group, ss)),
            ],
            out_specs=pl.BlockSpec((1, 1, ts), lambda bb, rr, ss, pos_r:
                                   (bb, rr, ss)),
        ),
        out_shape=jax.ShapeDtypeStruct((b, r_total, s), jnp.float32),
        interpret=interpret,
    )(pos.astype(jnp.int32), q_rep, k_cache_i8, k_scale)


# ------------------------------------------------------------------ AV ----
def _av_kernel(h2c_ref, a_ref, v_ref, o_ref, acc_scr, *, n_tiles):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a = a_ref[0, 0, :].astype(jnp.float32)[None, :]        # (1, Ts)
    v = v_ref[0, 0].astype(jnp.float32)                    # (Ts, hd)
    acc_scr[...] += jnp.dot(a, v, preferred_element_type=jnp.float32)

    @pl.when(s == n_tiles - 1)
    def _fin():
        o_ref[0, 0, :] = acc_scr[0, :].astype(o_ref.dtype)


def chai_av(a, v_cache, h2c, *, ts=512, interpret=None):
    """a: (B, R, S) normalized clustered scores; v_cache: (B, H, S, hd);
    h2c: (B, H) int32 head -> A-row map (scalar-prefetched: drives the A
    BlockSpec index_map). Returns (B, H, hd) fp32."""
    if interpret is None:
        interpret = _interpret_default()
    b, h, s, hd = v_cache.shape
    if h2c.ndim == 1:
        h2c = jnp.broadcast_to(h2c, (b, h))
    ts = min(ts, s)
    assert s % ts == 0
    n_tiles = s // ts
    kernel = functools.partial(_av_kernel, n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, n_tiles),
            in_specs=[
                pl.BlockSpec((1, 1, ts), lambda bb, hh, ss, h2c_r:
                             (bb, h2c_r[bb, hh], ss)),
                pl.BlockSpec((1, 1, ts, hd), lambda bb, hh, ss, h2c_r:
                             (bb, hh, ss, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, hd), lambda bb, hh, ss, h2c_r:
                                   (bb, hh, 0)),
            scratch_shapes=[pltpu.VMEM((1, hd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=interpret,
    )(h2c.astype(jnp.int32), a, v_cache)


# ------------------------------------------------------- paged AV ---------
def _paged_av_kernel(h2c_ref, bt_ref, a_ref, v_ref, o_ref, acc_scr, *,
                     n_tiles):
    # Same accumulate as _av_kernel; both scalar refs are consumed by the
    # index_maps (A row via h2c, V page via the block table).
    _av_kernel(h2c_ref, a_ref, v_ref, o_ref, acc_scr, n_tiles=n_tiles)


def paged_chai_av(a, v_pool, bt_v, h2c, *, interpret=None):
    """Paged broadcast-and-accumulate: head h reads the A row of its
    cluster (scalar-prefetched ``h2c``) and its own V rows from the page
    pool (scalar-prefetched block table) — the two gathers compose in
    one index_map pair. a: (B, R, S) normalized clustered scores with
    S == P * page; v_pool: (nP, H, page, hd); bt_v: (B, P) int32;
    h2c: (B, H) or (H,) int32. Returns (B, H, hd) fp32."""
    if interpret is None:
        interpret = _interpret_default()
    _, h, page, hd = v_pool.shape
    b = a.shape[0]
    if h2c.ndim == 1:
        h2c = jnp.broadcast_to(h2c, (b, h))
    n_pages = bt_v.shape[1]
    assert a.shape[2] == n_pages * page, (a.shape, n_pages, page)
    kernel = functools.partial(_paged_av_kernel, n_tiles=n_pages)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, h, n_pages),
            in_specs=[
                pl.BlockSpec((1, 1, page),
                             lambda bb, hh, ss, h2c_r, bt_r:
                             (bb, h2c_r[bb, hh], ss)),
                pl.BlockSpec((1, 1, page, hd),
                             lambda bb, hh, ss, h2c_r, bt_r:
                             (bt_r[bb, ss], hh, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, hd),
                                   lambda bb, hh, ss, h2c_r, bt_r:
                                   (bb, hh, 0)),
            scratch_shapes=[pltpu.VMEM((1, hd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=interpret,
    )(h2c.astype(jnp.int32), bt_v.astype(jnp.int32), a, v_pool)


# ------------------------------------------------- fused one-pass decode ---
def _fused_tile(pos_ref, q_ref, h2c_ref, k_ref, ks_ref, v_ref, vs_ref,
                out_refs, m_scr, l_scr, acc_scr, *, scale, ts, window,
                n_tiles, reps_per_group, v_rep, share_values, softcap=0.0,
                emit_state=False):
    """One (batch, S-tile) step of the fused clustered decode.

    Shared by the dense and paged variants — the paged caller only differs
    in how the K/V BlockSpecs locate the tile (block tables vs contiguous
    cache), so dense and paged produce bit-identical arithmetic for equal
    tile sizes (the engine's layout-parity guarantee).

    Scratch: ``m_scr``/``l_scr`` (R, 1) running max / normalizer per rep
    row; ``acc_scr`` (H, hd) per-member-head output accumulators (under
    ``share_values``: (R, hd) per-cluster — the h2c gather then happens at
    finalize, after normalization).

    ``emit_state``: instead of the finalized (H, hd) output, write the raw
    online-softmax triple — m (R,), l (R,), acc (rows_acc, hd) — so a
    caller can merge this pass with another (relay shared-prefix decode)
    before normalizing. The deferred jnp finalize (h2c gather + divide) is
    bitwise-identical to the in-kernel one-hot finalize."""
    b = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # (R, hd)
    k = k_ref[0].astype(jnp.float32)                     # (KVk, Ts, hd)
    r_total, hd = q.shape
    kv_k = k.shape[0]
    # Per-group rep scores: rep j reads the K rows of group j // rpg
    # (MHA clustered cache: KVk == R, rpg == 1 — plain batched matvec).
    q3 = q.reshape(kv_k, reps_per_group, hd)
    sc = jax.lax.dot_general(q3, k, (((2,), (2,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)
    if ks_ref is not None:   # int8: scores scaled by the per-row K scales
        sc = sc * ks_ref[0].astype(jnp.float32)[:, None, :]
    sc = sc.reshape(r_total, ts) * scale
    if softcap:
        # tanh logit softcap (gemma2): between QK-scale and the validity
        # mask, matching the jnp oracle's insertion point exactly.
        sc = softcap * jnp.tanh(sc / softcap)
    idx = s * ts + jax.lax.broadcasted_iota(jnp.int32, (1, ts), 1)
    pos = pos_ref[b]
    valid = idx <= pos
    if window:
        valid &= (pos - idx) < window
    sc = jnp.where(valid, sc, NEG_INF)                   # (R, Ts)

    m_prev = m_scr[...]                                  # (R, 1)
    m_new = jnp.maximum(
        jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True)), -1e30)
    alpha = jnp.exp(m_prev - m_new)                      # (R, 1)
    p = jnp.exp(sc - m_new)                              # (R, Ts)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)

    v = v_ref[0].astype(jnp.float32)                     # (KVv, Ts, hd)
    if vs_ref is not None:   # int8: dequant V rows before the AV dot
        v = v * vs_ref[0].astype(jnp.float32)[..., None]

    h2c = h2c_ref[0]                                     # (H,) int32
    h_total = h2c.shape[0]
    oneh = (h2c[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (h_total, r_total), 1)).astype(jnp.float32)   # (H, R)

    if share_values:
        # Clustered V (KVv == R): accumulate per cluster; broadcast to
        # member heads at finalize (after normalization).
        pv = jax.lax.dot_general(p[:, None, :], v,
                                 (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)[:, 0]
        acc_scr[...] = acc_scr[...] * alpha + pv         # (R, hd)
    else:
        # Broadcast the cluster rows to member heads (one-hot matmul: the
        # MXU-friendly spelling of the h2c gather), then per-head AV.
        p_full = jnp.dot(oneh, p,
                         preferred_element_type=jnp.float32)     # (H, Ts)
        alpha_full = jnp.dot(oneh, alpha,
                             preferred_element_type=jnp.float32)  # (H, 1)
        if v_rep > 1:        # GQA: head h reads the V rows of group h//qpk
            v = jnp.repeat(v, v_rep, axis=0)
        pv = jax.lax.dot_general(p_full[:, None, :], v,
                                 (((2,), (1,)), ((0,), (0,))),
                                 preferred_element_type=jnp.float32)[:, 0]
        acc_scr[...] = acc_scr[...] * alpha_full + pv    # (H, hd)

    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(s == n_tiles - 1)
    def _fin():
        if emit_state:
            m_ref, l_ref, acc_ref = out_refs
            m_ref[0] = m_scr[:, 0]
            l_ref[0] = l_scr[:, 0]
            acc_ref[0] = acc_scr[...]
        else:
            (o_ref,) = out_refs
            if share_values:
                out_r = acc_scr[...] / jnp.maximum(l_scr[...], 1e-37)
                out = jnp.dot(oneh, out_r,
                              preferred_element_type=jnp.float32)  # (H, hd)
            else:
                l_full = jnp.dot(oneh, l_scr[...],
                                 preferred_element_type=jnp.float32)
                out = acc_scr[...] / jnp.maximum(l_full, 1e-37)
            o_ref[0] = out.astype(o_ref.dtype)


def _fused_arg_router(n_prefetch, has_ks, has_vs, *, n_out=1, **flags):
    """Positional-ref unpacking for the optional int8 scale inputs.

    Kernel signature: [scalar-prefetch refs] q, h2c, k, [ks], v, [vs],
    <n_out output refs>, m, l, acc — the first prefetch ref is always
    ``pos``; paged adds the two block tables (consumed by the index maps
    only). ``n_out`` is 1 (finalized output) or 3 (emit_state m/l/acc)."""
    def kernel(*refs):
        pos_ref = refs[0]
        rest = list(refs[n_prefetch:])
        q_ref = rest.pop(0)
        h2c_ref = rest.pop(0)
        k_ref = rest.pop(0)
        ks_ref = rest.pop(0) if has_ks else None
        v_ref = rest.pop(0)
        vs_ref = rest.pop(0) if has_vs else None
        out_refs = tuple(rest[:n_out])
        m_scr, l_scr, acc_scr = rest[n_out:]
        _fused_tile(pos_ref, q_ref, h2c_ref, k_ref, ks_ref, v_ref, vs_ref,
                    out_refs, m_scr, l_scr, acc_scr, **flags)
    return kernel


def _fused_shapes(q_rep, v_rows, h2c, share_values):
    b, r_total, hd = q_rep.shape
    if h2c.ndim == 1:
        h2c = jnp.broadcast_to(h2c, (b, h2c.shape[0]))
    h_total = h2c.shape[1]
    if share_values:
        assert v_rows == r_total, (v_rows, r_total)
        v_rep = 1
    else:
        assert h_total % v_rows == 0, (h_total, v_rows)
        v_rep = h_total // v_rows
    rows_acc = r_total if share_values else h_total
    return b, r_total, hd, h2c, h_total, v_rep, rows_acc


def chai_fused_decode(q_rep, k_cache, v_cache, h2c, pos, *, k_scale=None,
                      v_scale=None, reps_per_group=1, share_values=False,
                      window=0, ts=512, softcap=0.0, emit_state=False,
                      interpret=None):
    """One-pass fused clustered decode over a dense cache.

    q_rep: (B, R, hd) rep-head queries; k_cache: (B, KVk, S, hd) with
    KVk * reps_per_group == R (MHA clustered cache: KVk == R); v_cache:
    (B, KVv, S, hd) — per-head V (KVv == H), per-group V (GQA: H % KVv
    == 0) or clustered V (share_values: KVv == R); h2c: (B, H) or (H,)
    int32 flat head -> rep-row map; pos: (B,) int32. int8 caches pass
    per-row scales via ``k_scale``/``v_scale`` (B, rows, S) and are
    dequantized in VMEM. Returns (B, H, hd) fp32 in ONE kernel launch —
    no (B, R, S) score tensor is ever materialized.

    ``emit_state``: return the raw mergeable online-softmax triple
    (m (B, R), l (B, R), acc (B, rows_acc, hd)) instead of the finalized
    output — the relay shared-prefix merge contract."""
    if interpret is None:
        interpret = _interpret_default()
    assert not (share_values and reps_per_group > 1), \
        "clustered V is an MHA-only ablation"
    s = k_cache.shape[2]
    kv_k, kv_v = k_cache.shape[1], v_cache.shape[1]
    b, r_total, hd, h2c, h_total, v_rep, rows_acc = _fused_shapes(
        q_rep, kv_v, h2c, share_values)
    assert kv_k * reps_per_group == r_total, (kv_k, reps_per_group, r_total)
    ts = min(ts, s)
    if s % ts:
        ts = s
    n_tiles = s // ts
    scale = 1.0 / math.sqrt(hd)

    in_specs = [
        pl.BlockSpec((1, r_total, hd), lambda bb, ss, pos_r: (bb, 0, 0)),
        pl.BlockSpec((1, h_total), lambda bb, ss, pos_r: (bb, 0)),
        pl.BlockSpec((1, kv_k, ts, hd), lambda bb, ss, pos_r:
                     (bb, 0, ss, 0)),
    ]
    inputs = [q_rep, h2c.astype(jnp.int32), k_cache]
    if k_scale is not None:
        in_specs.append(pl.BlockSpec((1, kv_k, ts), lambda bb, ss, pos_r:
                                     (bb, 0, ss)))
        inputs.append(k_scale)
    in_specs.append(pl.BlockSpec((1, kv_v, ts, hd), lambda bb, ss, pos_r:
                                 (bb, 0, ss, 0)))
    inputs.append(v_cache)
    if v_scale is not None:
        in_specs.append(pl.BlockSpec((1, kv_v, ts), lambda bb, ss, pos_r:
                                     (bb, 0, ss)))
        inputs.append(v_scale)

    if emit_state:
        out_specs = [
            pl.BlockSpec((1, r_total), lambda bb, ss, pos_r: (bb, 0)),
            pl.BlockSpec((1, r_total), lambda bb, ss, pos_r: (bb, 0)),
            pl.BlockSpec((1, rows_acc, hd),
                         lambda bb, ss, pos_r: (bb, 0, 0)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((b, r_total), jnp.float32),
            jax.ShapeDtypeStruct((b, r_total), jnp.float32),
            jax.ShapeDtypeStruct((b, rows_acc, hd), jnp.float32),
        ]
    else:
        out_specs = pl.BlockSpec((1, h_total, hd),
                                 lambda bb, ss, pos_r: (bb, 0, 0))
        out_shape = jax.ShapeDtypeStruct((b, h_total, hd), jnp.float32)
    kernel = _fused_arg_router(
        1, k_scale is not None, v_scale is not None,
        n_out=3 if emit_state else 1, scale=scale, ts=ts,
        window=window, n_tiles=n_tiles, reps_per_group=reps_per_group,
        v_rep=v_rep, share_values=share_values, softcap=softcap,
        emit_state=emit_state)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, n_tiles),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((r_total, 1), jnp.float32),
                pltpu.VMEM((r_total, 1), jnp.float32),
                pltpu.VMEM((rows_acc, hd), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(pos.astype(jnp.int32), *inputs)


def paged_chai_fused_decode(q_rep, k_pool, bt_k, v_pool, bt_v, h2c, pos, *,
                            k_scale_pool=None, v_scale_pool=None,
                            reps_per_group=1, share_values=False, window=0,
                            softcap=0.0, emit_state=False, interpret=None):
    """One-pass fused clustered decode over block-table page pools.

    q_rep: (B, R, hd); k_pool: (nP, KVk, page, hd) clustered pages (MHA:
    KVk == k_max) or the dense pool (GQA: KVk == n_kv_heads); v_pool:
    (nP, KVv, page, hd) — the dense per-head pool, or the clustered pool
    itself under ``share_values``; bt_k/bt_v: (B, P) int32 block tables
    (scalar-prefetched: they drive the K/V BlockSpec index maps, so pool
    pages stream HBM->VMEM exactly like dense tiles); h2c: (B, H) or
    (H,); pos: (B,). int8 pools pass ``k_scale_pool``/``v_scale_pool``
    (nP, rows, page) mirrors. Returns (B, H, hd) fp32 — one launch, no
    (B, R, S) scores, no densified pool gather.

    ``emit_state``: return (m (B, R), l (B, R), acc (B, rows_acc, hd))
    unfinalized — the relay suffix pass runs this over the private pages
    only and merges with the shared-prefix state before normalizing."""
    if interpret is None:
        interpret = _interpret_default()
    assert not (share_values and reps_per_group > 1), \
        "clustered V is an MHA-only ablation"
    kv_k, page = k_pool.shape[1], k_pool.shape[2]
    kv_v = v_pool.shape[1]
    b, r_total, hd, h2c, h_total, v_rep, rows_acc = _fused_shapes(
        q_rep, kv_v, h2c, share_values)
    assert kv_k * reps_per_group == r_total, (kv_k, reps_per_group, r_total)
    n_pages = bt_k.shape[1]
    assert bt_v.shape == bt_k.shape == (b, n_pages)
    scale = 1.0 / math.sqrt(hd)

    in_specs = [
        pl.BlockSpec((1, r_total, hd),
                     lambda bb, ss, pos_r, btk_r, btv_r: (bb, 0, 0)),
        pl.BlockSpec((1, h_total),
                     lambda bb, ss, pos_r, btk_r, btv_r: (bb, 0)),
        pl.BlockSpec((1, kv_k, page, hd),
                     lambda bb, ss, pos_r, btk_r, btv_r:
                     (btk_r[bb, ss], 0, 0, 0)),
    ]
    inputs = [q_rep, h2c.astype(jnp.int32), k_pool]
    if k_scale_pool is not None:
        in_specs.append(pl.BlockSpec((1, kv_k, page),
                                     lambda bb, ss, pos_r, btk_r, btv_r:
                                     (btk_r[bb, ss], 0, 0)))
        inputs.append(k_scale_pool)
    in_specs.append(pl.BlockSpec((1, kv_v, page, hd),
                                 lambda bb, ss, pos_r, btk_r, btv_r:
                                 (btv_r[bb, ss], 0, 0, 0)))
    inputs.append(v_pool)
    if v_scale_pool is not None:
        in_specs.append(pl.BlockSpec((1, kv_v, page),
                                     lambda bb, ss, pos_r, btk_r, btv_r:
                                     (btv_r[bb, ss], 0, 0)))
        inputs.append(v_scale_pool)

    if emit_state:
        out_specs = [
            pl.BlockSpec((1, r_total),
                         lambda bb, ss, pos_r, btk_r, btv_r: (bb, 0)),
            pl.BlockSpec((1, r_total),
                         lambda bb, ss, pos_r, btk_r, btv_r: (bb, 0)),
            pl.BlockSpec((1, rows_acc, hd),
                         lambda bb, ss, pos_r, btk_r, btv_r: (bb, 0, 0)),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((b, r_total), jnp.float32),
            jax.ShapeDtypeStruct((b, r_total), jnp.float32),
            jax.ShapeDtypeStruct((b, rows_acc, hd), jnp.float32),
        ]
    else:
        out_specs = pl.BlockSpec((1, h_total, hd),
                                 lambda bb, ss, pos_r, btk_r, btv_r:
                                 (bb, 0, 0))
        out_shape = jax.ShapeDtypeStruct((b, h_total, hd), jnp.float32)
    kernel = _fused_arg_router(
        3, k_scale_pool is not None, v_scale_pool is not None,
        n_out=3 if emit_state else 1, scale=scale,
        ts=page, window=window, n_tiles=n_pages,
        reps_per_group=reps_per_group, v_rep=v_rep,
        share_values=share_values, softcap=softcap, emit_state=emit_state)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=(b, n_pages),
            in_specs=in_specs,
            out_specs=out_specs,
            scratch_shapes=[
                pltpu.VMEM((r_total, 1), jnp.float32),
                pltpu.VMEM((r_total, 1), jnp.float32),
                pltpu.VMEM((rows_acc, hd), jnp.float32),
            ],
        ),
        out_shape=out_shape,
        interpret=interpret,
    )(pos.astype(jnp.int32), bt_k.astype(jnp.int32),
      bt_v.astype(jnp.int32), *inputs)


# --------------------------------------- relay shared-prefix decode --------
def _relay_prefix_kernel(plen_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                         krow_ref, arow_ref, vrow_ref, m_ref, l_ref,
                         acc_ref, m_scr, l_scr, acc_scr, *, scale, ts,
                         n_tiles, softcap=0.0):
    """One (group, S-tile) step of the relay shared-prefix pass.

    All member slots of a relay group attend the SAME packed resident
    prefix K/V — the kernel batches their rep queries along one row axis
    (NR = Nmax * R) so the prefix streams HBM->VMEM once per group, not
    once per slot. Per-member cluster assignments differ, so three int32
    row maps route the gathers (spelled as one-hot matmuls, the MXU
    idiom): ``k_row`` query-row -> prefix K row, ``a_row`` accumulator
    row -> query row (the h2c broadcast, deferred from the suffix merge),
    ``v_row`` accumulator row -> prefix V row.

    Masking is ``idx < plen`` only — every prefix position precedes every
    decode query, so there is no causal constraint inside the prefix; the
    same mask hides the zero-padded tail of shorter groups."""
    g = pl.program_id(0)
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                     # (NR, hd)
    k = k_ref[0].astype(jnp.float32)                     # (KV, Ts, hd)
    nr, hd = q.shape
    kv = k.shape[0]
    k_row = krow_ref[0]                                  # (NR,) int32
    oneh_k = (k_row[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (nr, kv), 1)).astype(jnp.float32)     # (NR, KV)
    kg = jnp.dot(oneh_k, k.reshape(kv, ts * hd),
                 preferred_element_type=jnp.float32).reshape(nr, ts, hd)
    sc = jax.lax.dot_general(q[:, None, :], kg,
                             (((2,), (2,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)[:, 0]
    if ks_ref is not None:   # int8: scores scaled by per-(row, pos) scales
        ksg = jnp.dot(oneh_k, ks_ref[0].astype(jnp.float32),
                      preferred_element_type=jnp.float32)  # (NR, Ts)
        sc = sc * ksg
    sc = sc * scale
    if softcap:
        sc = softcap * jnp.tanh(sc / softcap)
    idx = s * ts + jax.lax.broadcasted_iota(jnp.int32, (1, ts), 1)
    sc = jnp.where(idx < plen_ref[g], sc, NEG_INF)       # (NR, Ts)

    m_prev = m_scr[...]                                  # (NR, 1)
    m_new = jnp.maximum(
        jnp.maximum(m_prev, jnp.max(sc, axis=1, keepdims=True)), -1e30)
    alpha = jnp.exp(m_prev - m_new)                      # (NR, 1)
    p = jnp.exp(sc - m_new)                              # (NR, Ts)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_new

    v = v_ref[0].astype(jnp.float32)                     # (VR, Ts, hd)
    if vs_ref is not None:
        v = v * vs_ref[0].astype(jnp.float32)[..., None]
    a_row = arow_ref[0]                                  # (A,) int32
    v_row = vrow_ref[0]                                  # (A,) int32
    a_total = a_row.shape[0]
    vr = v.shape[0]
    oneh_a = (a_row[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (a_total, nr), 1)).astype(jnp.float32)  # (A, NR)
    p_a = jnp.dot(oneh_a, p, preferred_element_type=jnp.float32)
    alpha_a = jnp.dot(oneh_a, alpha, preferred_element_type=jnp.float32)
    oneh_v = (v_row[:, None] == jax.lax.broadcasted_iota(
        jnp.int32, (a_total, vr), 1)).astype(jnp.float32)  # (A, VR)
    vg = jnp.dot(oneh_v, v.reshape(vr, ts * hd),
                 preferred_element_type=jnp.float32).reshape(
                     a_total, ts, hd)
    pv = jax.lax.dot_general(p_a[:, None, :], vg,
                             (((2,), (1,)), ((0,), (0,))),
                             preferred_element_type=jnp.float32)[:, 0]
    acc_scr[...] = acc_scr[...] * alpha_a + pv           # (A, hd)

    @pl.when(s == n_tiles - 1)
    def _fin():
        m_ref[0] = m_scr[:, 0]
        l_ref[0] = l_scr[:, 0]
        acc_ref[0] = acc_scr[...]


def relay_prefix_decode(q, k, v, k_row, a_row, v_row, plen, *,
                        k_scale=None, v_scale=None, ts=0, softcap=0.0,
                        interpret=None):
    """One batched shared-prefix attention pass per relay group.

    q: (G, NR, hd) member rep queries stacked per group (NR = Nmax * R,
    zero-padded members compute garbage rows that the engine's scatter
    discards); k: (G, KV, Sp, hd) packed resident prefix K (the radix
    chain's dense rows); v: (G, VR, Sp, hd) packed resident prefix V;
    k_row/a_row/v_row: (G, NR)/(G, A)/(G, A) int32 routing maps (see
    ``_relay_prefix_kernel``); plen: (G,) int32 valid prefix lengths
    (scalar-prefetched; masks the zero-padded tail — Sp is the page-
    aligned max over groups). int8 prefixes pass ``k_scale``/``v_scale``
    (G, rows, Sp) mirrors (share_values V codes ride scale-less, matching
    the clustered-pool reinterpret semantics). Returns the mergeable
    triple (m (G, NR), l (G, NR), acc (G, A, hd)) f32 — combine with the
    suffix ``emit_state`` triple via ``ops.merge_decode_states``."""
    if interpret is None:
        interpret = _interpret_default()
    g, nr, hd = q.shape
    kv, sp = k.shape[1], k.shape[2]
    vr = v.shape[1]
    a_total = a_row.shape[1]
    assert k_row.shape == (g, nr) and v_row.shape == (g, a_total)
    assert v.shape[2] == sp
    ts = ts or sp
    ts = min(ts, sp)
    if sp % ts:
        ts = sp
    n_tiles = sp // ts
    scale = 1.0 / math.sqrt(hd)

    in_specs = [
        pl.BlockSpec((1, nr, hd), lambda gg, ss, plen_r: (gg, 0, 0)),
        pl.BlockSpec((1, kv, ts, hd), lambda gg, ss, plen_r:
                     (gg, 0, ss, 0)),
    ]
    inputs = [q, k]
    if k_scale is not None:
        in_specs.append(pl.BlockSpec((1, kv, ts), lambda gg, ss, plen_r:
                                     (gg, 0, ss)))
        inputs.append(k_scale)
    in_specs.append(pl.BlockSpec((1, vr, ts, hd), lambda gg, ss, plen_r:
                                 (gg, 0, ss, 0)))
    inputs.append(v)
    if v_scale is not None:
        in_specs.append(pl.BlockSpec((1, vr, ts), lambda gg, ss, plen_r:
                                     (gg, 0, ss)))
        inputs.append(v_scale)
    in_specs += [
        pl.BlockSpec((1, nr), lambda gg, ss, plen_r: (gg, 0)),
        pl.BlockSpec((1, a_total), lambda gg, ss, plen_r: (gg, 0)),
        pl.BlockSpec((1, a_total), lambda gg, ss, plen_r: (gg, 0)),
    ]
    inputs += [k_row.astype(jnp.int32), a_row.astype(jnp.int32),
               v_row.astype(jnp.int32)]

    has_ks, has_vs = k_scale is not None, v_scale is not None

    def kernel(*refs):
        plen_ref = refs[0]
        rest = list(refs[1:])
        q_ref = rest.pop(0)
        k_ref = rest.pop(0)
        ks_ref = rest.pop(0) if has_ks else None
        v_ref = rest.pop(0)
        vs_ref = rest.pop(0) if has_vs else None
        (krow_ref, arow_ref, vrow_ref, m_ref, l_ref, acc_ref,
         m_scr, l_scr, acc_scr) = rest
        _relay_prefix_kernel(plen_ref, q_ref, k_ref, ks_ref, v_ref, vs_ref,
                             krow_ref, arow_ref, vrow_ref, m_ref, l_ref,
                             acc_ref, m_scr, l_scr, acc_scr, scale=scale,
                             ts=ts, n_tiles=n_tiles, softcap=softcap)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(g, n_tiles),
            in_specs=in_specs,
            out_specs=[
                pl.BlockSpec((1, nr), lambda gg, ss, plen_r: (gg, 0)),
                pl.BlockSpec((1, nr), lambda gg, ss, plen_r: (gg, 0)),
                pl.BlockSpec((1, a_total, hd), lambda gg, ss, plen_r:
                             (gg, 0, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((nr, 1), jnp.float32),
                pltpu.VMEM((nr, 1), jnp.float32),
                pltpu.VMEM((a_total, hd), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((g, nr), jnp.float32),
            jax.ShapeDtypeStruct((g, nr), jnp.float32),
            jax.ShapeDtypeStruct((g, a_total, hd), jnp.float32),
        ],
        interpret=interpret,
    )(plen.astype(jnp.int32), *inputs)
