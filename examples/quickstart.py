"""Quickstart: CHAI in 60 lines.

Builds a reduced LLaMA-style model, runs the three CHAI phases by hand
(prefill -> MHA warmup -> cluster -> compact -> CHAI decode), and prints
the KV-cache saving + per-step attention FLOPs.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced
from repro.core.cache import (add_score_buffer, compact_kv, kv_cache_bytes,
                              pop_score_buffer)
from repro.core.clustering import identify_membership
from repro.launch import steps as steps_mod
from repro.models import transformer as tfm


def main():
    # 1. A reduced same-family config of the paper's model (LLaMA-7B, MHA).
    cfg = reduced(get_config("chai-llama-7b")).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True)
    print(f"model: {cfg.name} reduced: {cfg.n_layers}L d={cfg.d_model} "
          f"H={cfg.n_heads} (MHA={cfg.is_mha})")
    print(f"offline cluster counts per layer: {cfg.chai_cluster_counts()}")

    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    b, t, max_seq = 2, 16, 64
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)

    # 2. PREFILL: full forward, dense KV cache.
    prefill = jax.jit(steps_mod.make_serve_prefill(cfg, b, max_seq))
    logits, state = prefill(params, {"tokens": prompt})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)

    # 3. WARMUP: 5 MHA decode steps, accumulating per-head score features.
    state = add_score_buffer(state, cfg, b)
    mha_step = jax.jit(steps_mod.make_serve_step(cfg, chai=False))
    for _ in range(cfg.chai.warmup_tokens):
        logits, state = mha_step(params, {"tokens": tok}, state)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)

    # 4. CLUSTER + COMPACT: per-request membership, K-cache gather.
    state, scores = pop_score_buffer(state)
    ctx = identify_membership(scores, cfg)
    print(f"cluster membership (layer 0, request 0): "
          f"{np.asarray(ctx['h2c'])[0, 0]}")
    state = compact_kv(state, ctx, cfg)
    print(f"K cache rows: {cfg.n_heads} -> {state['kg_chai'].shape[2]}")

    # 5. STEADY: Clustered Head Attention decode.
    chai_step = jax.jit(steps_mod.make_serve_step(cfg, chai=True))
    out = []
    for _ in range(8):
        logits, state = chai_step(params, {"tokens": tok}, state, ctx)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(np.asarray(tok))
    print(f"generated (request 0): {[int(o[0]) for o in out]}")

    full = kv_cache_bytes(cfg, b, max_seq, chai=False)
    ch = kv_cache_bytes(cfg, b, max_seq, chai=True)
    print(f"KV cache: {full:,} B (MHA) -> {ch:,} B (CHAI), "
          f"saving {100 * (1 - ch / full):.1f}%")


if __name__ == "__main__":
    main()
