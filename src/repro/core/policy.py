"""Attention policies: MHA baseline, CHAI variants, and the paper's
comparison baselines (DejaVu head sparsity, SpAtten cascade pruning, random
clustering from Fig 1/14).

These are *full-sequence* reference implementations used by the accuracy
and FLOPs benchmarks (Tables 1-4, Figs 1, 14). The production decode path
lives in repro.core.chai_attention; both share the clustering code so the
benchmark measures the same algorithm the engine runs.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.clustering import standardize
from repro.core.kmeans import kmeans, representatives

POLICIES = ("mha", "chai", "chai-static", "chai-qkv", "dejavu", "spatten",
            "random")


class PolicyOut(NamedTuple):
    out: jnp.ndarray          # (B, T, H, hd)
    score_flops: jnp.ndarray  # scalar — QK^T + softmax-ish flops actually done
    info: dict


def _full_scores(q, k):
    """q: (B,T,H,hd), k: (B,T,H,hd) -> causal softmax scores (B,H,T,T)."""
    b, t, h, hd = q.shape
    sc = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((t, t), bool))
    sc = jnp.where(mask[None, None], sc, -2e38)
    return jax.nn.softmax(sc, axis=-1)


def _score_flops(b, t, h_eff, hd):
    return jnp.asarray(2.0 * b * t * t * h_eff * hd, jnp.float32)


def _cluster_heads(a, n_clusters, warmup_tokens, iters=12):
    """Cluster heads from warmup-prefix scores. a: (B,H,T,T) probs.
    Features per head: scores of the first `warmup_tokens` query rows
    (paper: cluster after 5 decode steps). Returns (h2c (B,H), reps (B,k))."""
    b, h, t, _ = a.shape
    w = min(warmup_tokens, t)
    feats = a[:, :, :w, :].reshape(b, h, -1)

    def one(f):
        fz = standardize(f)
        assign, centers, _ = kmeans(fz, n_clusters, iters)
        reps, _ = representatives(fz, assign, centers, n_clusters)
        return assign.astype(jnp.int32), reps

    return jax.vmap(one)(feats)


def apply_policy(policy, q, k, v, *, n_clusters=None, warmup_tokens=5,
                 sparsity=0.5, h2c_static=None, reps_static=None,
                 token_keep=0.7, key=None):
    """Run attention under ``policy``. q,k,v: (B,T,H,hd) (MHA layout).

    Returns PolicyOut. CHAI policies compute scores only for representative
    heads (plus the warmup rows for clustering); DejaVu zeroes the most
    uniform heads; SpAtten drops low-importance tokens then heads.
    """
    b, t, h, hd = q.shape

    if policy == "mha":
        a = _full_scores(q, k)
        out = jnp.einsum("bhts,bshd->bthd", a, v.astype(jnp.float32))
        return PolicyOut(out.astype(q.dtype), _score_flops(b, t, h, hd),
                         {"probs": a})

    if policy in ("chai", "chai-qkv", "chai-static", "random"):
        kk = n_clusters or max(1, h // 2)
        if policy == "chai-static":
            assert h2c_static is not None and reps_static is not None
            h2c = jnp.broadcast_to(h2c_static, (b, h))
            reps = jnp.broadcast_to(reps_static, (b, kk))
        elif policy == "random":
            key = key if key is not None else jax.random.PRNGKey(0)
            h2c1 = jax.random.randint(key, (h,), 0, kk)
            # ensure every cluster has a member: first kk heads pinned
            h2c1 = h2c1.at[:kk].set(jnp.arange(kk))
            h2c = jnp.broadcast_to(h2c1, (b, h))
            reps = jnp.broadcast_to(jnp.arange(kk), (b, kk))
        else:
            a_warm = _full_scores(q, k)      # warmup observation (MHA cost
            # paid once on the first `warmup_tokens` rows; we charge it below)
            h2c, reps = _cluster_heads(a_warm, kk, warmup_tokens)
        # clustered scores: only representative heads
        q_rep = jnp.take_along_axis(q, reps[:, None, :, None], axis=2)
        k_rep = jnp.take_along_axis(k, reps[:, None, :, None], axis=2)
        a_rep = _full_scores(q_rep, k_rep)   # (B, k, T, T)
        a_full = jnp.take_along_axis(a_rep, h2c[:, :, None, None], axis=1)
        if policy == "chai-qkv":
            v_rep = jnp.take_along_axis(v, reps[:, None, :, None], axis=2)
            o_rep = jnp.einsum("bhts,bshd->bthd", a_rep,
                               v_rep.astype(jnp.float32))
            out = jnp.take_along_axis(o_rep, h2c[:, None, :, None], axis=2)
        else:
            out = jnp.einsum("bhts,bshd->bthd", a_full,
                             v.astype(jnp.float32))
        warm_cost = (_score_flops(b, warmup_tokens, h, hd)
                     if policy in ("chai", "chai-qkv") else 0.0)
        return PolicyOut(out.astype(q.dtype),
                         _score_flops(b, t, kk, hd) + warm_cost,
                         {"h2c": h2c, "reps": reps})

    if policy == "dejavu":
        a = _full_scores(q, k)
        # uniformity = negative entropy distance from uniform: prune heads
        # whose score rows are closest to uniform (the DejaVu criterion).
        ent = -jnp.sum(jnp.where(a > 0, a * jnp.log(a + 1e-20), 0.0), -1)
        row_cnt = jnp.log(jnp.arange(1, t + 1, dtype=jnp.float32))
        uniformity = (ent / jnp.maximum(row_cnt, 1e-6)).mean(-1)  # (B, H)
        n_prune = int(sparsity * h)
        order = jnp.argsort(-uniformity, axis=-1)        # most uniform first
        pruned = jnp.zeros((b, h), bool)
        pruned = pruned.at[jnp.arange(b)[:, None], order[:, :n_prune]].set(
            True)
        out = jnp.einsum("bhts,bshd->bthd", a, v.astype(jnp.float32))
        out = jnp.where(pruned[:, None, :, None], 0.0, out)
        return PolicyOut(out.astype(q.dtype),
                         _score_flops(b, t, h - n_prune, hd),
                         {"pruned": pruned})

    if policy == "spatten":
        a = _full_scores(q, k)
        # cascade token pruning: cumulative attention importance per token
        imp = a.sum(axis=(1, 2))                          # (B, S)
        n_keep = max(1, int(token_keep * t))
        kept = jnp.argsort(-imp, axis=-1)[:, :n_keep]
        keep_mask = jnp.zeros((b, t), bool).at[
            jnp.arange(b)[:, None], kept].set(True)
        a_mask = jnp.where(keep_mask[:, None, None, :], a, 0.0)
        a_mask = a_mask / jnp.maximum(a_mask.sum(-1, keepdims=True), 1e-9)
        # head pruning by accumulated head importance
        head_imp = a_mask.max(-1).mean(-1)                # (B, H)
        n_prune = int(sparsity * h)
        order = jnp.argsort(head_imp, axis=-1)            # least important
        pruned = jnp.zeros((b, h), bool).at[
            jnp.arange(b)[:, None], order[:, :n_prune]].set(True)
        out = jnp.einsum("bhts,bshd->bthd", a_mask, v.astype(jnp.float32))
        out = jnp.where(pruned[:, None, :, None], 0.0, out)
        return PolicyOut(out.astype(q.dtype),
                         _score_flops(b, n_keep, h - n_prune, hd),
                         {"pruned": pruned, "kept_tokens": keep_mask})

    raise ValueError(f"unknown policy {policy!r}")
