"""MusicGen-Large [arXiv:2306.05284]: decoder-only over EnCodec tokens.

True MHA (kv == heads == 32): the paper's exact regime — CHAI drops K-cache
rows of non-representative heads. The EnCodec frontend is a stub; inputs are
precomputed frame embeddings per the assignment.
"""
from repro.configs.base import ModelConfig, CHAIConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    activation="gelu",
    frontend="audio",
    rope_theta=10000.0,
    chai=CHAIConfig(enabled=True),
))
