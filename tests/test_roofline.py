"""Roofline derivation: HLO collective parsing + analytic model flops."""
import pytest

from repro.configs.base import SHAPES, get_config
from repro.launch import roofline as rl

HLO = """
ENTRY %main {
  %ar = bf16[256,1024]{1,0} all-reduce(bf16[256,1024]{1,0} %x), replica_groups={}
  %ag = f32[512,64]{1,0} all-gather(f32[256,64]{1,0} %y), dimensions={0}
  %rs.1 = f32[128]{0} reduce-scatter(f32[256]{0} %z), dimensions={0}
  %cp = u8[100]{0} collective-permute(u8[100]{0} %w)
  %a2a = bf16[16,16]{1,0} all-to-all(bf16[16,16]{1,0} %v), dimensions={0}
  %ags = (f32[8]{0}, f32[16]{0}) all-gather-start(f32[8]{0} %q), dimensions={0}
  %agd = f32[16]{0} all-gather-done((f32[8]{0}, f32[16]{0}) %ags)
  %dot = f32[4,4]{1,0} dot(f32[4,8]{1,0} %a, f32[8,4]{1,0} %b)
}
"""


def test_collective_bytes_parses_kinds():
    total, kinds, n = rl.collective_bytes(HLO)
    assert kinds["all-reduce"] == 256 * 1024 * 2
    assert kinds["all-gather"] == 512 * 64 * 4 + 16 * 4 + 8 * 4
    assert kinds["reduce-scatter"] == 256 * 4
    assert kinds["collective-permute"] == 100
    assert kinds["all-to-all"] == 16 * 16 * 2
    assert n == 6                       # -done not double counted
    assert total == sum(kinds.values())


def test_roofline_bottleneck():
    r = rl.Roofline(flops_per_dev=197e12, bytes_per_dev=1.0,
                    coll_bytes_per_dev=1.0, coll_breakdown={},
                    n_collectives=0)
    assert r.bottleneck == "compute"
    assert r.t_compute == pytest.approx(1.0)
    r2 = rl.Roofline(1.0, 819e9, 1.0, {}, 0)
    assert r2.bottleneck == "memory"
    r3 = rl.Roofline(1.0, 1.0, 50e9, {}, 0)
    assert r3.bottleneck == "collective"


def test_model_flops_train_vs_decode():
    cfg = get_config("chai-llama-7b")
    tr = rl.model_flops(cfg, SHAPES["train_4k"])
    de = rl.model_flops(cfg, SHAPES["decode_32k"])
    n = cfg.param_count()
    assert tr == pytest.approx(6 * n * 4096 * 256)
    assert de == pytest.approx(2 * n * 128)


def test_model_flops_moe_uses_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
    tr = rl.model_flops(cfg, SHAPES["train_4k"])
    assert tr == pytest.approx(6 * cfg.active_param_count() * 4096 * 256)
