"""Logical-axis -> PartitionSpec rule engine.

Every tensor in the system carries a tuple of *logical* axis names. This
module maps those names onto mesh axes with divisibility-aware fallbacks so a
single rule table serves all 10 assigned architectures on the fixed
production meshes (16x16 single-pod, 2x16x16 multi-pod).

Key behaviours:
  * A mesh axis is assigned to at most one tensor dim (PartitionSpec rule).
  * A candidate is skipped unless the dim size is divisible by the mesh-axis
    size (so e.g. gemma3's 8 query heads fall through to head_dim sharding
    on a 16-way model axis).
  * ``batch`` prefers the combined ("pod","data") group on multi-pod meshes;
    ``seq`` picks up the data axis only when batch could not (automatic
    context-parallel fallback for long_500k's global_batch=1).
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisCandidate = Union[str, Tuple[str, ...]]


class Ax:
    """Leaf wrapper for a tuple of logical axis names (pytree-safe)."""
    __slots__ = ("names",)

    def __init__(self, *names):
        self.names = tuple(names)

    def __repr__(self):
        return f"Ax{self.names}"

    def __eq__(self, other):
        return isinstance(other, Ax) and self.names == other.names

    def __hash__(self):
        return hash(self.names)

# Ordered candidates per logical axis name. Tuples = combined mesh axes.
DEFAULT_RULES: dict = {
    "batch":      [("pod", "data"), ("data",), ("pod",)],
    "seq":        [("pod", "data"), ("data",)],   # CP fallback (decode B=1)
    "seq_nosplit": [],
    "vocab":      [("model",)],
    "embed":      [],                 # replicated (activations row dim)
    "embed_tp":   [("model",)],       # TP'd embed dim (e.g. rwkv channel dims)
    "heads":      [("model",)],
    "kv_heads":   [("model",)],
    "head_dim":   [("model",)],       # fallback target when heads fail
    "mlp":        [("model",)],
    "experts":    [("model",)],
    "expert_mlp": [("model",)],       # fallback if experts not divisible
    "rnn":        [("model",)],
    "conv":       [],
    "layers":     [],                 # stacked-scan leading dim: never sharded
    "lora":       [],
    "capacity":   [],
    "clusters":   [],                 # CHAI representative-head axis
}


def spec_for(shape: Sequence[int], logical: Sequence[Optional[str]],
             mesh: Mesh, rules: Optional[dict] = None) -> P:
    """Compute a PartitionSpec for ``shape`` with logical axis names."""
    rules = rules or DEFAULT_RULES
    assert len(shape) == len(logical), (shape, logical)
    axis_sizes = dict(zip(mesh.axis_names, mesh.shape.values())) \
        if isinstance(mesh.shape, dict) else dict(mesh.shape)
    used: set = set()
    out: list = []
    for dim, name in zip(shape, logical):
        assigned = None
        for cand in rules.get(name, []) if name else []:
            group = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(a not in axis_sizes for a in group):
                continue
            if any(a in used for a in group):
                continue
            size = math.prod(axis_sizes[a] for a in group)
            if size > 1 and dim % size == 0:
                assigned = group if len(group) > 1 else group[0]
                used.update(group)
                break
        out.append(assigned)
    # Trim trailing Nones for cleanliness.
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def sharding_for(shape, logical, mesh, rules=None) -> NamedSharding:
    return NamedSharding(mesh, spec_for(shape, logical, mesh, rules))


def tree_shardings(shapes_tree, logical_tree, mesh, rules=None):
    """Map matching pytrees of shapes and ``Ax`` logical names -> shardings."""
    return jax.tree.map(
        lambda s, l: sharding_for(tuple(s.shape), l.names, mesh, rules),
        shapes_tree, logical_tree)


def tree_specs(shapes_tree, logical_tree, mesh, rules=None):
    return jax.tree.map(
        lambda s, l: spec_for(tuple(s.shape), l.names, mesh, rules),
        shapes_tree, logical_tree)


# ------------------------------------------------------------- ZeRO-1 ------
def zero_spec(shape, base_spec: P, mesh) -> P:
    """Shard one extra dim of an *elementwise-updated* tensor (optimizer
    moments, gradient accumulators) over the data(+pod) axes — ZeRO-1.

    The update math is elementwise, so ANY extra partitioning is valid;
    GSPMD inserts the reduce-scatter (grads->moments) and all-gather
    (updated params) automatically. Picks the first dim not already
    sharded in ``base_spec`` whose size divides the combined data axes;
    returns ``base_spec`` unchanged if none divides.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    group = tuple(a for a in ("pod", "data") if a in axis_sizes)
    if not group:
        return base_spec
    dsize = math.prod(axis_sizes[a] for a in group)
    if dsize <= 1:
        return base_spec
    spec = list(base_spec) + [None] * (len(shape) - len(base_spec))
    for i, dim in enumerate(shape):
        if spec[i] is None and dim % dsize == 0:
            spec[i] = group if len(group) > 1 else group[0]
            return P(*spec)
    return base_spec


def zero_shardings(shapes_tree, logical_tree, mesh, rules=None):
    """NamedShardings for optimizer state under ZeRO-1 (param spec + one
    data-sharded dim)."""
    def one(s, l):
        base = spec_for(tuple(s.shape), l.names, mesh, rules)
        return NamedSharding(mesh, zero_spec(tuple(s.shape), base, mesh))
    return jax.tree.map(one, shapes_tree, logical_tree)
