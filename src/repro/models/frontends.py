"""Modality frontend stubs (per assignment: audio/vision frontends provide
precomputed frame/patch embeddings; only a linear adapter is real)."""
from __future__ import annotations

import jax.numpy as jnp


def adapt(embeddings, p):
    """embeddings: (B, T, d_in) precomputed frontend outputs -> (B, T, d)."""
    return jnp.einsum("btd,de->bte", embeddings, p["adapter"])
