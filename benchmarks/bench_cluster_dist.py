"""Paper Fig 13: distribution of cluster sizes (skew: one big cluster).

Identifies membership on many synthetic contexts with the trained tiny
model and histograms cluster sizes per layer."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import save_result, tiny_trained
from repro.core.cache import add_score_buffer, pop_score_buffer
from repro.core.clustering import identify_membership
from repro.models import transformer as tfm


def run(n_contexts=8):
    cfg, params, pipe, _ = tiny_trained()
    cfg = cfg.with_chai(enabled=True, cluster_counts=(4,) * cfg.n_attn_layers)
    b, t0, s = 4, 24, 64
    sizes = []
    for c in range(n_contexts // b):
        toks = jnp.asarray(pipe.batch(1000 + c)["tokens"][:b, :t0])
        state = tfm.init_decode_state(cfg, b, s)
        _, state, _ = tfm.forward_fullseq(params, cfg, toks, state=state)
        state = add_score_buffer(state, cfg, b)
        nxt = toks[:, -1]
        for _ in range(cfg.chai.warmup_tokens):
            logits, state = tfm.decode_step(params, cfg, nxt, state)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        _, scores = pop_score_buffer(state)
        ctx = identify_membership(scores, cfg)
        h2c = np.asarray(ctx["h2c"])            # (nA, B, H)
        k = 4
        for l in range(h2c.shape[0]):
            for bb in range(b):
                counts = np.bincount(h2c[l, bb], minlength=k)
                sizes.append(sorted(counts.tolist(), reverse=True))

    sizes = np.asarray(sizes)
    result = {
        "proxy_note": "cluster-size distribution over contexts "
                      "(paper Fig 13: layer-18 LLaMA-7B on C4)",
        "mean_sorted_cluster_sizes": sizes.mean(axis=0).tolist(),
        "largest_cluster_mean_frac":
            float(sizes[:, 0].mean() / sizes.sum(axis=1).mean()),
        "paper_claim": "skewed: one or two large clusters dominate",
        "claim_check": {
            "skewed": float(sizes[:, 0].mean()) >
                      float(sizes[:, -1].mean()) + 0.5,
        },
    }
    save_result("bench_cluster_dist", result)
    return result


if __name__ == "__main__":
    print(run())
