"""Perf-iteration features: EP MoE, int8 KV cache, ZeRO sharding rules.

The expert-parallel MoE and the int8 cache are correctness-tested here on
CPU (single device / small meshes); their roofline effect is measured by
the dry-run (EXPERIMENTS.md §Perf).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import cache as chai_cache
from repro.launch import steps as steps_mod
from repro.models import moe, transformer as tfm
from repro.sharding import rules
from repro.sharding.context import current_ctx, sharding_ctx


# ------------------------------------------------------------- EP MoE ----
def _moe_cfg():
    cfg = reduced(get_config("deepseek-moe-16b"), d_model=32, n_experts=8,
                  top_k=2, moe_d_ff=16)
    return cfg.replace(dtype="float32", capacity_factor=4.0)


def _moe_params(cfg, rng):
    d, e, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    mk = lambda *s: jnp.asarray(rng.normal(size=s) * 0.1, jnp.float32)
    p = {"router": mk(d, e), "w_gate": mk(e, d, f), "w_up": mk(e, d, f),
         "w_down": mk(e, f, d)}
    if cfg.n_shared_experts:
        sf = cfg.n_shared_experts * f
        p.update(shared_gate=mk(d, sf), shared_up=mk(d, sf),
                 shared_down=mk(sf, d))
    return p


def test_ep_moe_matches_reference_on_1d_mesh(rng):
    """Single-device mesh: all_to_all over size-1 axes == identity; the
    EP path must equal the capacity reference exactly."""
    cfg = _moe_cfg()
    p = _moe_params(cfg, rng)
    x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)), jnp.float32)
    y_ref = moe.moe_ffn(x, p, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding_ctx(mesh, batch_axes=("data",)) as ctx:
        y_ep = jax.jit(lambda x, p: moe.moe_ffn_ep(x, p, cfg, ctx))(x, p)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep),
                               rtol=1e-5, atol=1e-5)


def test_ep_moe_falls_back_on_indivisible(rng):
    """Odd token counts fall back to the capacity impl, not crash."""
    cfg = _moe_cfg()
    p = _moe_params(cfg, rng)
    x = jnp.asarray(rng.normal(size=(3, 7, cfg.d_model)), jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with sharding_ctx(mesh, batch_axes=("data",)) as ctx:
        y = moe.moe_ffn_ep(x, p, cfg, ctx)
    np.testing.assert_allclose(np.asarray(y), np.asarray(moe.moe_ffn(x, p,
                                                                     cfg)),
                               rtol=1e-5, atol=1e-5)


def test_forward_fullseq_ep_without_ctx_is_reference(rng):
    """moe_impl='ep' with no active ctx must equal the capacity impl."""
    cfg = reduced(get_config("qwen3-moe-30b-a3b")).replace(
        dtype="float32", capacity_factor=4.0)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    assert current_ctx() is None
    l1, _, _ = tfm.forward_fullseq(params, cfg, toks, moe_impl="capacity")
    l2, _, _ = tfm.forward_fullseq(params, cfg, toks, moe_impl="ep")
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------ int8 KV ----
def test_int8_kv_quant_roundtrip(rng):
    x = jnp.asarray(rng.normal(size=(3, 4, 16)), jnp.float32)
    q, s = chai_cache.quant_rows(x)
    back = chai_cache.dequant_rows(q, s)
    err = np.abs(np.asarray(back - x))
    # max error <= half a quantization step per row
    assert (err <= np.asarray(s)[..., None] / 2 + 1e-6).all()


def test_int8_kv_decode_tracks_f32(rng):
    cfg = reduced(get_config("chai-llama-7b"), n_layers=2, d_model=64,
                  n_heads=8, vocab=128).replace(dtype="float32")
    cfg8 = cfg.replace(kv_cache_dtype="int8")
    params = tfm.init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.asarray(rng.integers(0, 128, (2, 8)), jnp.int32)

    outs = {}
    for c in (cfg, cfg8):
        pre = steps_mod.make_serve_prefill(c, 2, 32)
        logits, state = pre(params, {"tokens": toks})
        step = steps_mod.make_serve_step(c, chai=False)
        for t in ((3, 4), (5, 6)):
            logits, state = step(params, {"tokens": jnp.asarray(t)}, state)
        outs[c.kv_cache_dtype] = logits
    rel = float(jnp.abs(outs["int8"] - outs[""]).max()
                / jnp.abs(outs[""]).max())
    assert rel < 0.05, rel


def test_int8_kv_compact_carries_scales(rng):
    cfg = reduced(get_config("musicgen-large"), n_heads=8).replace(
        dtype="float32", kv_cache_dtype="int8", frontend="none")
    cfg = cfg.with_chai(enabled=True, cluster_counts=(3,) * cfg.n_attn_layers)
    b, s = 2, 16
    state = tfm.init_decode_state(cfg, b, s)
    assert state["kg"].dtype == jnp.int8 and "kg_scale" in state
    reps = jnp.zeros((cfg.n_attn_layers, b, 3), jnp.int32)
    new = chai_cache.compact_kv(state, {"reps": reps}, cfg)
    assert "kg_chai_scale" in new
    assert new["kg_chai"].dtype == jnp.int8
    assert new["kg_chai_scale"].shape == (cfg.n_global_layers, b, 3, s)


def test_int8_kv_cache_bytes_halved():
    cfg = get_config("chai-llama-7b")
    full = chai_cache.kv_cache_bytes(cfg, 1, 2048)
    i8 = chai_cache.kv_cache_bytes(cfg.replace(kv_cache_dtype="int8"),
                                   1, 2048)
    assert 0.48 < i8 / full < 0.55      # ~2x minus scale overhead


# ---------------------------------------------------------------- ZeRO ----
def test_zero_spec_adds_data_axis():
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    # data axis size 1 -> unchanged
    assert rules.zero_spec((8, 4), P(None, None), mesh) == P(None, None)


def test_zero_spec_divisibility(rng):
    """zero_spec never shards an indivisible dim (property over shapes)."""
    import math
    from jax.sharding import PartitionSpec as P

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    mesh = FakeMesh()
    for shape in [(48, 8, 768), (34, 64), (7, 3), (256,), (1, 16)]:
        spec = rules.zero_spec(shape, P(*([None] * len(shape))), mesh)
        for dim, s in zip(shape, tuple(spec) + (None,) * 9):
            if s is not None:
                size = 16 if isinstance(s, str) else math.prod(
                    [16 for _ in s])
                assert dim % size == 0
