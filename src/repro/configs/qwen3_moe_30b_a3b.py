"""Qwen3-MoE 30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 128 experts, top-8."""
from repro.configs.base import ModelConfig, CHAIConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,
    vocab_size=151936,
    n_experts=128,
    top_k=8,
    moe_d_ff=768,
    activation="silu",
    qk_norm=True,
    rope_theta=1000000.0,
    chai=CHAIConfig(enabled=True),
))
