"""RWKV-6 "Finch" 1.6B [arXiv:2404.05892]: attention-free, data-dependent decay.

CHAI is INAPPLICABLE (no attention heads / no KV cache) — built without the
technique per DESIGN.md §Arch-applicability.
"""
from repro.configs.base import ModelConfig, CHAIConfig, register, RWKV

CONFIG = register(ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    layer_types=(RWKV,) * 24,
    rwkv_head_dim=64,
    chai=CHAIConfig(enabled=False),
))
