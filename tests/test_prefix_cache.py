"""Shared-prefix KV reuse: radix cache, COW paged pool, cached-aware
prefill.

Correctness contract: the prefix cache is a pure *work-skipping* layer —
every greedy token must be identical to a cold run of the same prompt,
whether the request misses, partially hits (suffix-only prefill over
aliased pages), fully hits a CHAI snapshot (STEADY entry, zero prefill
attention FLOPs, zero WARMUP/CLUSTER steps), or is replayed entirely
host-side. Refcounts must drop to zero after eviction + slot churn.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import cache as chai_cache
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.prefix_cache import PrefixCache

MHA_ARCH = "chai-llama-7b"
GQA_ARCH = "nemotron-4-15b"
PS = 16


def _cfg(arch, **chai_kw):
    cfg = reduced(get_config(arch), n_layers=2, d_model=32, d_ff=64,
                  vocab=64).replace(dtype="float32")
    return cfg.with_chai(enabled=True, warmup_tokens=3, **chai_kw)


def _engine(cfg, params, *, prefix_cache=True, slots=2, max_seq=64,
            **ecfg_kw):
    return ServingEngine(cfg, params,
                        EngineConfig(batch_slots=slots, max_seq=max_seq,
                                     page_size=PS,
                                     prefix_cache=prefix_cache, **ecfg_kw))


def _cold_tokens(cfg, params, prompt, max_new, **ecfg_kw):
    eng = _engine(cfg, params, prefix_cache=False, **ecfg_kw)
    eng.submit(prompt, max_new_tokens=max_new, uid=0)
    return eng.run()[0].generated


def _by_uid(done):
    return {r.uid: r for r in done}


# ------------------------------------------------------- PagePool refcount
def test_page_pool_refcount_shared_pages_freed_at_zero():
    pool = chai_cache.PagePool(8, PS)
    pages = pool.alloc(2)
    pool.incref(pages)                      # a second holder
    assert pool.refcount(pages[0]) == 2
    pool.free(pages)                        # first holder drops
    assert pool.pages_in_use == 2           # still held
    pool.free(pages)                        # second holder drops -> freed
    assert pool.pages_in_use == 0
    with pytest.raises(AssertionError):     # rc 0: double free
        pool.free(pages[:1])
    with pytest.raises(AssertionError):     # incref of a free page
        pool.incref(pages[:1])


# --------------------------------------------------------- radix tree unit
def _mk_cache(dense=32, chai=16):
    dense_pool = chai_cache.PagePool(dense, PS)
    chai_pool = chai_cache.PagePool(chai, PS)
    return PrefixCache(dense_pool, chai_pool, PS), dense_pool, chai_pool


def test_radix_match_insert_and_divergence():
    cache, pool, _ = _mk_cache()
    rng = np.random.default_rng(0)
    a = rng.integers(0, 64, size=3 * PS)
    kg, vg = pool.alloc(3), pool.alloc(3)
    assert cache.insert(a, kg, vg) == 3
    # full match is capped at (len-1)//PS so one suffix token remains
    assert len(cache.match(a)) == 2
    assert len(cache.match(np.concatenate([a, [1]]))) == 3
    # diverging INSIDE block 2 shares only the first block's node
    b = a.copy()
    b[PS + 3] ^= 1
    m = cache.match(np.concatenate([b, [1]]))
    assert len(m) == 1 and m[0].kg_page == kg[0]
    # re-inserting the same prompt creates nothing new
    assert cache.insert(a, kg, vg) == 0
    # each cached block holds one reference on each of its pages
    assert all(pool.refcount(p) == 2 for p in kg + vg)


def test_radix_lru_eviction_pins_locked_and_frees_pages():
    cache, pool, _ = _mk_cache()
    rng = np.random.default_rng(1)
    a = rng.integers(0, 64, size=2 * PS)
    b = rng.integers(0, 64, size=2 * PS)
    ka, va = pool.alloc(2), pool.alloc(2)
    kb, vb = pool.alloc(2), pool.alloc(2)
    cache.insert(a, ka, va)
    cache.insert(b, kb, vb)
    pool.free(ka + va + kb + vb)            # slots retired; cache holds
    assert pool.pages_in_use == 8
    nodes_b = cache.match(np.concatenate([b, [0]]))
    cache.lock(nodes_b)                     # an active slot pins b's chain
    assert cache.evict_until(dense_free=pool.free_pages + 4)
    # a's chain went (LRU, unlocked); b's leaf is pinned transitively? No:
    # only unlocked leaves are evictable — b's chain survives.
    assert cache.match(np.concatenate([a, [0]])) == []
    assert len(cache.match(np.concatenate([b, [0]]))) == 2
    cache.unlock(nodes_b)
    cache.clear()
    assert pool.pages_in_use == 0           # freed-at-zero: nothing leaks


# ------------------------------------------------ engine parity: the matrix
@pytest.mark.parametrize("arch,chai_kw,cfg_kw", [
    (MHA_ARCH, {}, {}),
    (MHA_ARCH, {}, {"kv_cache_dtype": "int8"}),
    (MHA_ARCH, {"share_values": True}, {}),
    (MHA_ARCH, {"share_values": True}, {"kv_cache_dtype": "int8"}),
    (GQA_ARCH, {}, {}),
    (GQA_ARCH, {}, {"kv_cache_dtype": "int8"}),
])
@pytest.mark.slow
def test_hit_miss_partial_parity_vs_cold(arch, chai_kw, cfg_kw):
    """miss -> snapshot hit -> partial hit, every flavour: greedy tokens
    identical to a cold engine without the cache."""
    cfg = _cfg(arch, **chai_kw).replace(**cfg_kw)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=24)   # 1 block + tail
    part = np.concatenate([prompt[:PS],
                           rng.integers(0, cfg.vocab_size, size=8)])
    cold = _cold_tokens(cfg, params, prompt, 12)
    cold_part = _cold_tokens(cfg, params, part, 12)

    eng = _engine(cfg, params)
    eng.submit(prompt, max_new_tokens=12, uid=0)        # miss
    miss = _by_uid(eng.run())[0]
    assert miss.cache_hit == "" and miss.generated == cold

    eng.submit(prompt, max_new_tokens=12, uid=1)        # warm
    warm = _by_uid(eng.run())[1]
    assert warm.generated == cold
    if eng.chai_clustered:      # MHA: full-prompt CHAI snapshot
        assert warm.cache_hit == "snapshot"
        assert warm.prefill_tokens == 0
    else:                       # GQA: dense block reuse only
        assert warm.cache_hit == "prefix"
        assert warm.prefill_tokens == len(prompt) - PS

    eng.submit(part, max_new_tokens=12, uid=2)          # partial
    partial = _by_uid(eng.run())[2]
    assert partial.cache_hit == "prefix"
    assert partial.cached_tokens == PS
    assert partial.prefill_tokens == 8
    assert partial.generated == cold_part

    # drain + drop the cache: every page refcount reaches zero
    eng.prefix_cache.clear()
    assert eng.dense_pool.pages_in_use == 0
    if eng.chai_pool is not None:
        assert eng.chai_pool.pages_in_use == 0


def test_snapshot_skips_warmup_and_cluster_entirely():
    """Acceptance: a warm full-prompt request performs zero prefill
    attention FLOPs and zero WARMUP/CLUSTER transitions, yet emits greedy
    tokens bit-identical to the cold path (replayed warmup tokens + the
    same STEADY state)."""
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=24)
    eng = _engine(cfg, params)
    eng.submit(prompt, max_new_tokens=12, uid=0)
    cold = _by_uid(eng.run())[0]
    clusters_after_cold = eng.cluster_transitions
    assert clusters_after_cold == 1

    eng.submit(prompt, max_new_tokens=12, uid=1)
    warm = _by_uid(eng.run())[1]
    assert warm.cache_hit == "snapshot"
    assert warm.prefill_tokens == 0                  # no prefill forward
    assert eng.cluster_transitions == clusters_after_cold   # no CLUSTER
    assert warm.generated == cold.generated          # bit-identical

    # replay-only: snapshot covers max_new -> no slot, no device work
    steps_before = eng.steps_executed
    eng.submit(prompt, max_new_tokens=3, uid=2)
    replay = _by_uid(eng.run())[2]
    assert replay.cache_hit == "replay" and replay.slot == -1
    assert eng.steps_executed == steps_before
    assert replay.generated == cold.generated[:3]


def test_cached_membership_equals_cold_membership():
    """The snapshot's per-layer cluster membership is the exact ctx the
    cold path computed (identical membership => identical CHAI math)."""
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompt = rng.integers(0, cfg.vocab_size, size=24)

    eng = _engine(cfg, params)
    eng.submit(prompt, max_new_tokens=12, uid=0)
    cold = _by_uid(eng.run())[0]
    snap = eng.prefix_cache.snapshot_for(prompt)
    assert snap is not None
    # the cold slot's membership survives in the engine's persistent ctx
    for key in ("h2c", "reps"):
        np.testing.assert_array_equal(
            snap.ctx[key], np.asarray(eng._dev_ctx[key][:, cold.slot]))


@pytest.mark.slow
def test_cow_divergence_after_shared_prefix():
    """Two concurrent requests share a cached block then diverge: each
    writes only its own pages (the shared page is read-only; the
    snapshot's partial tail was copied), so both match their cold runs
    and the shared pages survive both retirements."""
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    base = rng.integers(0, cfg.vocab_size, size=PS)
    p1 = np.concatenate([base, rng.integers(0, cfg.vocab_size, size=6)])
    p2 = np.concatenate([base, rng.integers(0, cfg.vocab_size, size=6)])
    cold1 = _cold_tokens(cfg, params, p1, 14)
    cold2 = _cold_tokens(cfg, params, p2, 14)

    eng = _engine(cfg, params)
    eng.submit(p1, max_new_tokens=14, uid=0)            # seeds the block
    eng.run()
    # both diverging requests in ONE wave: slot 2 aliases the block slot 1
    # seeded, while slot 1 (same wave) still holds it — shared, read-only
    eng.submit(p1, max_new_tokens=14, uid=1)
    eng.submit(p2, max_new_tokens=14, uid=2)
    done = _by_uid(eng.run())
    assert done[1].generated == cold1
    assert done[2].generated == cold2
    assert done[2].cache_hit in ("prefix", "snapshot")
    eng.prefix_cache.clear()
    assert eng.dense_pool.pages_in_use == 0
    assert eng.chai_pool.pages_in_use == 0


@pytest.mark.slow
def test_concurrent_snapshot_hits_share_pages():
    """Acceptance: >= 2 concurrent warm requests over one shared prompt
    allocate strictly fewer pages than the no-sharing baseline (full
    pages aliased; only partial tails + headroom are per-slot)."""
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, size=32)   # 2 full blocks

    eng = _engine(cfg, params)
    eng.submit(prompt, max_new_tokens=16, uid=0)
    eng.run()
    base_stats = eng.prefix_stats()
    assert base_stats["snapshots"] == 1

    # no-sharing baseline: peak pages of 2 cold requests side by side
    engb = _engine(cfg, params, prefix_cache=False)
    engb.submit(prompt, max_new_tokens=16, uid=0)
    engb.submit(prompt, max_new_tokens=16, uid=1)
    engb.run()
    cold_peak = max(h["dense_pages"] + h["chai_pages"]
                    for h in engb.kv_bytes_history)

    for uid in (1, 2):
        eng.submit(prompt, max_new_tokens=16, uid=uid)
    hist0 = len(eng.kv_bytes_history)
    done = _by_uid(eng.run())
    assert done[1].cache_hit == done[2].cache_hit == "snapshot"
    assert done[1].generated == done[2].generated
    warm_peak = max(h["dense_pages"] + h["chai_pages"]
                    for h in eng.kv_bytes_history[hist0:])
    assert warm_peak < cold_peak    # shared pages counted once
    eng.prefix_cache.clear()
    assert eng.dense_pool.pages_in_use == 0
    assert eng.chai_pool.pages_in_use == 0


@pytest.mark.slow
def test_eviction_under_pressure_then_no_leaks():
    """A pool too small to keep cache + new work evicts LRU entries to
    admit fresh requests; everything still completes with cold-parity
    tokens and zero pages leak after the final clear."""
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=24) for _ in range(4)]
    colds = [_cold_tokens(cfg, params, p, 8) for p in prompts]

    need = chai_cache.pages_needed(24 + 8, PS)
    eng = _engine(cfg, params, slots=1,
                  num_pages=2 * need + 3, num_chai_pages=need + 2)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new_tokens=8, uid=i)
    done = _by_uid(eng.run())
    for i in range(4):
        assert done[i].generated == colds[i], i
    stats = eng.prefix_stats()
    assert stats["evicted_blocks"] + stats["evicted_snapshots"] > 0
    eng.prefix_cache.clear()
    assert eng.dense_pool.pages_in_use == 0
    assert eng.chai_pool.pages_in_use == 0


def test_prefix_cache_config_validation():
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):     # dense layout cannot share pages
        _engine(cfg, params, kv_layout="dense")
    gem = reduced(get_config("gemma2-9b"), n_layers=2, d_model=32,
                  d_ff=64, vocab=64).replace(dtype="float32")
    if gem.n_local_layers:              # local rings are not paged
        gp = tfm.init_params(gem, jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            _engine(gem, gp)
