"""Training steps: microbatched gradient accumulation + compressed cross-pod
reduction (the distributed-optimization layer on top of launch/steps.py).

Three variants, all lowered by the dry-run:
  * ``make_train_step`` (launch/steps.py) — plain fused step; XLA inserts
    full-precision all-reduces from the shardings. Baseline.
  * ``make_microbatched_train_step`` — splits the global batch into
    ``n_micro`` sequential microbatches with an f32 gradient accumulator.
    On real hardware this (a) caps activation memory and (b) staggers the
    per-microbatch backward so XLA's latency-hiding scheduler overlaps the
    reduce-scatter of microbatch i with the compute of microbatch i+1.
  * ``make_compressed_train_step`` — shard_map *manual over the pod axis
    only* (in-pod axes stay Auto/GSPMD). Gradients are reduced in-pod at
    full precision by GSPMD, then all-reduced across pods in int8 with
    error feedback (optim/compression.py). The wire cost of the slow axis
    drops 4x vs f32 / 2x vs bf16.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.steps import LB_COEF, Z_COEF, cross_entropy, make_loss_fn
from repro.optim import adamw, compression


def _split_micro(batch, n_micro):
    """(B, ...) -> (n_micro, B/n_micro, ...) for every leaf."""
    def r(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree.map(r, batch)


def make_microbatched_train_step(cfg: ModelConfig, *, n_micro: int,
                                 remat=True, moe_impl="capacity",
                                 lr_kw: Optional[dict] = None,
                                 unroll=False):
    """Gradient accumulation over ``n_micro`` sequential microbatches."""
    loss_fn = make_loss_fn(cfg, remat=remat, moe_impl=moe_impl,
                           unroll=unroll)
    lr_kw = lr_kw or {}

    def train_step(params, opt_state, batch):
        micro = _split_micro(batch, n_micro)

        def body(carry, mb):
            acc, loss_acc, ce_acc = carry
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return (acc, loss_acc + loss, ce_acc + metrics["ce"]), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (gsum, loss_sum, ce_sum), _ = jax.lax.scan(
            body, (zeros, 0.0, 0.0), micro)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        lr = adamw.cosine_lr(opt_state.step, **lr_kw) if lr_kw else None
        params, opt_state, om = adamw.update(grads, opt_state, params, lr=lr)
        return params, opt_state, {
            "loss": loss_sum / n_micro, "ce": ce_sum / n_micro,
            "load_balance": jnp.zeros(()), "router_z": jnp.zeros(()), **om}

    return train_step


def make_compressed_train_step(cfg: ModelConfig, mesh, *, pod_axis="pod",
                               remat=True, moe_impl="capacity",
                               lr_kw: Optional[dict] = None):
    """Train step with int8 error-feedback cross-pod gradient all-reduce.

    Signature: (params, opt_state, residual, batch) ->
               (params', opt_state', residual', metrics).
    Requires a mesh with a ``pod`` axis; params/opt replicated across pods,
    batch split on the pod axis (its in-pod sharding stays GSPMD Auto).
    """
    assert pod_axis in mesh.axis_names, mesh.axis_names
    loss_fn = make_loss_fn(cfg, remat=remat, moe_impl=moe_impl)
    lr_kw = lr_kw or {}
    n_pods = dict(zip(mesh.axis_names, mesh.devices.shape))[pod_axis]

    def body(params, opt_state, residual, batch):
        # Pod-local loss over the pod's slice of the global batch. GSPMD
        # (auto axes) still partitions compute/grads within the pod.
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        grads, residual = compression.compressed_psum(
            grads, residual, pod_axis)
        lr = adamw.cosine_lr(opt_state.step, **lr_kw) if lr_kw else None
        params, opt_state, om = adamw.update(grads, opt_state, params, lr=lr)
        loss = jax.lax.pmean(loss, pod_axis)
        ce = jax.lax.pmean(metrics["ce"], pod_axis)
        return params, opt_state, residual, {
            "loss": loss, "ce": ce,
            "load_balance": metrics["load_balance"],
            "router_z": metrics["router_z"], **om}

    rep = lambda tree: jax.tree.map(lambda _: P(), tree)

    def train_step(params, opt_state, residual, batch):
        wrapped = compression.wrap_pod_manual(
            body, mesh,
            in_specs=(rep(params), rep(opt_state), rep(residual),
                      jax.tree.map(lambda _: P(pod_axis), batch)),
            out_specs=(rep(params), rep(opt_state), rep(residual),
                       {"loss": P(), "ce": P(), "load_balance": P(),
                        "router_z": P(), "grad_norm": P(), "lr": P()}),
            pod_axis=pod_axis)
        return wrapped(params, opt_state, residual, batch)

    return train_step
