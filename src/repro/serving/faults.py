"""Typed failure taxonomy + deterministic, seeded fault injection.

The serving stack distinguishes two failure classes:

* ``RequestError`` — a failure attributable to ONE request. The engine
  quarantines that request (abort + refcount-exact page release + a
  typed ``StepOutput`` with ``finish_reason="error"``) and the rest of
  the batch keeps decoding. Subclasses keep backwards-compatible bases:
  ``CapacityError`` is-a ``MemoryError`` (the historical page-budget
  signal) and ``ValidationError`` is-a ``ValueError`` (the historical
  ``add_request`` rejections), so callers catching the old types keep
  working while new callers can catch the taxonomy root.
* ``EngineFault`` — the engine itself is wrong (an invariant audit
  found pool/block-table/phase corruption, or the degraded decode path
  failed too). Not recoverable per-request: frontends broadcast it to
  every open stream and stop the driver.

``FaultInjector`` is a deterministic, seeded injector threaded through
the engine's named sites (``SITES``). A fault *plan* is a list of
``FaultSpec``s; whether a spec fires at a given call depends only on
``(seed, spec index, site, step, uid)`` — never on wall clock, call
order across sites, or process state — so any plan is replayable
byte-for-byte against the same workload. Every firing is recorded in
``fired`` (site, step, uid, mode), which doubles as the soak report's
"affected requests" ledger.

Injection sites (where the engine consults the injector):

========================  ==================================================
``pool.alloc``            admission planning (``_plan_admission``): mode
                          ``transient`` blocks the plan this step (retried);
                          mode ``error`` quarantines the queued request.
``swap.corrupt``          preemption swap-out (``_preempt_slot``): corrupts
                          the host-side resume payload AFTER its checksum
                          was taken, so swap-in detects the damage.
``swap.in``               preemption swap-in (``_swap_in_slot``): fails the
                          restore outright (same quarantine path a checksum
                          mismatch takes).
``snapshot.restore``      CHAI-snapshot admission: the restore fails; the
                          engine drops the snapshot and re-plans the request
                          cold (greedy tokens are unchanged by design).
``relay.residency``       relay group formation: the groups formed this
                          step dissolve to the per-request decode path.
``kernel.decode``         the fused decode dispatch: the engine falls back
                          to the jnp reference path (``degraded_decode``).
``kernel.prefill``        slot prefill at admission (``_admit_to_slot``,
                          after the slot enters PREFILL): the request is
                          quarantined; the slot tears down refcount-exactly.
``kernel.cluster``        the WARMUP→CLUSTER transition
                          (``_cluster_transitions``): the transitioning
                          request is quarantined before clustering mutates
                          the pools; others keep decoding.
``step.logits``           per-slot logits poisoning (NaN): the NaN/Inf
                          guard quarantines the slot, others are untouched.
``offload.out``           prefix-cache demotion (``_demote_entry``): mode
                          ``corrupt`` damages the host-tier copy AFTER its
                          CRC stamp (caught at promotion); any other mode
                          declines the demotion — the entry drops instead
                          (losing a cache entry is always safe).
``offload.in``            tier promotion (``_promote_entry`` /
                          ``_swap_in_slot``): a cache-entry promotion fails
                          and the entry is dropped + re-planned cold; a
                          swapped-out request's fetch failure quarantines
                          that request only.
========================  ==================================================
"""
from __future__ import annotations

import dataclasses
import hashlib
import zlib
from typing import List, Optional

import numpy as np

# -- taxonomy ---------------------------------------------------------------


class RequestError(Exception):
    """Request-isolatable failure: quarantine ONE request, keep the
    batch running. ``uid`` names the request when known."""

    def __init__(self, msg: str, *, uid: Optional[int] = None):
        super().__init__(msg)
        self.uid = uid


class ValidationError(RequestError, ValueError):
    """The request itself is malformed (rejected at ``add_request``)."""


class CapacityError(RequestError, MemoryError):
    """The request can NEVER be admitted: its page needs exceed pool
    capacity even with the prefix cache drained (the historical
    ``MemoryError`` page-budget gate, now carrying the uid)."""


class QuarantineError(RequestError):
    """Mid-flight state damage attributable to one request (injected
    fault, swap-in checksum mismatch, non-finite logits): the request
    is typed-failed; its pages return refcount-exactly."""


class SnapshotRestoreError(RequestError):
    """A CHAI-snapshot restore failed. Recoverable: the engine drops
    the snapshot and re-plans the admission cold."""


class EngineFault(RuntimeError):
    """The engine state itself is corrupt (invariant breach) or the
    last-resort decode path failed: broadcast to every stream."""

    def __init__(self, msg: str, violations=()):
        self.violations = list(violations)
        if self.violations:
            msg = msg + "\n  - " + "\n  - ".join(self.violations)
        super().__init__(msg)


class InjectedFault(Exception):
    """Raised by injector arms standing in for a real runtime failure
    (e.g. a kernel launch error) — never escapes the engine: the
    handler at the site converts it into recovery or a typed error."""

    def __init__(self, site: str, msg: str = ""):
        super().__init__(msg or f"injected fault at {site}")
        self.site = site


# -- injector ---------------------------------------------------------------

SITES = frozenset({
    "pool.alloc", "swap.corrupt", "swap.in", "snapshot.restore",
    "relay.residency", "kernel.decode", "kernel.prefill", "kernel.cluster",
    "step.logits", "offload.out", "offload.in",
})

#: spec modes with meaning at their sites (see module docstring)
MODES = frozenset({"error", "transient", "corrupt", "nan"})


@dataclasses.dataclass
class FaultSpec:
    """One arm of a fault plan.

    site   one of ``SITES``.
    mode   what the site does when the arm fires (site-specific).
    step   fire only at this engine step (-1 = any step).
    uid    fire only for this request uid (-1 = any request).
    count  firings before the arm is spent (-1 = unlimited).
    p      per-eligible-call firing probability; decided by a stable
           hash of (seed, arm index, site, step, uid), NOT a stateful
           RNG, so replays are byte-for-byte identical.
    """
    site: str
    mode: str = "error"
    step: int = -1
    uid: int = -1
    count: int = 1
    p: float = 1.0

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}; "
                             f"sites: {sorted(SITES)}")
        if self.mode not in MODES:
            raise ValueError(f"unknown fault mode {self.mode!r}; "
                             f"modes: {sorted(MODES)}")
        if not 0.0 < self.p <= 1.0:
            raise ValueError(f"p must be in (0, 1], got {self.p}")


class FaultInjector:
    """Deterministic seeded injector over a list of ``FaultSpec``s.

    ``fire(site, step=, uid=)`` returns the first eligible spec (or
    None) and logs the firing. Eligibility is pure in (spec, site,
    step, uid) plus the spec's remaining count; the probabilistic roll
    hashes ``(seed, arm, site, step, uid)`` so two runs over the same
    workload fire identically.
    """

    def __init__(self, specs: List[FaultSpec], *, seed: int = 0):
        self.specs = list(specs)
        self.seed = int(seed)
        self._remaining = [s.count for s in self.specs]
        self.fired: List[dict] = []

    def _roll(self, idx: int, spec: FaultSpec, step: int, uid: int) -> bool:
        if spec.p >= 1.0:
            return True
        key = f"{self.seed}:{idx}:{spec.site}:{step}:{uid}".encode()
        h = int.from_bytes(hashlib.blake2b(key, digest_size=8).digest(),
                           "big")
        return (h / float(1 << 64)) < spec.p

    def fire(self, site: str, *, step: int = -1,
             uid: int = -1) -> Optional[FaultSpec]:
        for idx, spec in enumerate(self.specs):
            if spec.site != site:
                continue
            if spec.step != -1 and spec.step != step:
                continue
            if spec.uid != -1 and spec.uid != uid:
                continue
            if self._remaining[idx] == 0:
                continue
            if not self._roll(idx, spec, step, uid):
                continue
            if self._remaining[idx] > 0:
                self._remaining[idx] -= 1
            self.fired.append({"site": site, "step": int(step),
                               "uid": int(uid), "mode": spec.mode,
                               "arm": idx})
            return spec
        return None

    def report(self) -> dict:
        """JSON-ready plan + firing log (the soak report embeds it)."""
        return {"seed": self.seed,
                "specs": [dataclasses.asdict(s) for s in self.specs],
                "fired": list(self.fired)}


# -- host-payload integrity helpers ----------------------------------------

def checksum_arrays(tree) -> int:
    """Order-stable CRC32 over a (possibly nested) dict of numpy arrays
    — the preemption swap-out stamps its resume payload with this and
    swap-in verifies it, so host-side corruption of a victim's KV never
    reaches the device."""
    crc = 0
    if isinstance(tree, dict):
        for k in sorted(tree):
            crc = zlib.crc32(str(k).encode(), crc)
            crc = zlib.crc32(checksum_arrays(tree[k]).to_bytes(4, "big"),
                             crc)
        return crc
    arr = np.ascontiguousarray(np.asarray(tree))
    crc = zlib.crc32(str(arr.dtype).encode() + str(arr.shape).encode(), crc)
    return zlib.crc32(arr.tobytes(), crc)


def corrupt_arrays(tree: dict, *, seed: int = 0) -> bool:
    """Deterministically flip bits in the first non-empty array of a
    nested dict (in sorted-key order) — the ``swap.corrupt`` arm's
    payload damage. The damaged leaf is REPLACED with a flipped copy
    (``jax.device_get`` leaves are read-only). Returns True if anything
    was corrupted."""
    for k in sorted(tree):
        v = tree[k]
        if isinstance(v, dict):
            if corrupt_arrays(v, seed=seed):
                return True
            continue
        arr = np.asarray(v)
        if arr.size == 0:
            continue
        buf = np.array(arr, copy=True)
        flat = buf.view(np.uint8).reshape(-1)
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, flat.size, size=min(8, flat.size))
        flat[idx] ^= 0xFF
        tree[k] = buf
        return True
    return False
