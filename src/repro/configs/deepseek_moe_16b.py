"""DeepSeekMoE 16B [arXiv:2401.06066]: 2 shared + 64 routed top-6, fine-grained.

Layer 0 uses a dense FFN (as in the released model); remaining 27 layers MoE.
n_kv_heads == n_heads == 16 => MHA: CHAI's K-cache saving applies fully.
"""
from repro.configs.base import (ModelConfig, CHAIConfig, register,
                                FFN_DENSE, FFN_MOE)

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=10944,                      # dense layer-0 FFN width
    vocab_size=102400,
    ffn_types=(FFN_DENSE,) + (FFN_MOE,) * 27,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    moe_d_ff=1408,
    activation="silu",
    rope_theta=10000.0,
    chai=CHAIConfig(enabled=True),
))
