"""Streaming + multi-turn serving through the LLM frontend.

Demonstrates the step-driven API surface on a tiny CPU config:

1. ``LLM.generate`` — sync batch with per-request SamplingParams
   (one greedy, one seeded top-k/top-p).
2. ``LLM.stream`` — incremental chunks; the first token arrives at
   admission, long before the request completes.
3. ``abort`` — cancel a stream mid-flight; the page pools drain back to
   their baseline (printed).
4. ``Session`` — 3-turn chat over the radix prefix cache: turns 2/3
   alias the pages earlier turns filled and prefill only the new
   message (cached vs forwarded token counts printed).

Run: ``PYTHONPATH=src python examples/api_stream.py``
"""
import numpy as np

import jax

from repro.configs.base import get_config, reduced
from repro.models import transformer as tfm
from repro.serving import EngineConfig, LLM, SamplingParams, Session


def main():
    cfg = reduced(get_config("nemotron-4-15b"), n_layers=2, d_model=32,
                  d_ff=64, vocab=64).replace(dtype="float32")
    cfg = cfg.with_chai(enabled=True, warmup_tokens=3)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    llm = LLM(cfg, params, EngineConfig(batch_slots=2, max_seq=128,
                                        prefix_cache=True))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=12) for _ in range(2)]

    # 1. sync batch, mixed per-request sampling
    outs = llm.generate(prompts,
                        [SamplingParams(max_new_tokens=12),
                         SamplingParams(temperature=0.8, top_k=16,
                                        top_p=0.95, seed=7,
                                        max_new_tokens=12)])
    for o in outs:
        print(f"[generate] uid={o.uid} finish={o.finish_reason} "
              f"tokens={o.token_ids}")

    # 2. streaming
    print("[stream]", end=" ", flush=True)
    for chunk in llm.stream(prompts[0], SamplingParams(max_new_tokens=12)):
        print(*chunk.token_ids, end=" ", flush=True)
    print("(done)")

    # 3. abort mid-stream; pools drain to baseline
    base = llm.core.dense_pool.counters()
    it = llm.stream(rng.integers(0, cfg.vocab_size, size=12),
                    SamplingParams(max_new_tokens=64))
    first = next(it)
    llm.abort(first.uid)
    list(it)
    print(f"[abort] after 1 chunk: pools back to baseline = "
          f"{llm.core.dense_pool.counters() == base}")

    # 4. 3-turn session over the prefix cache
    ses = Session(llm, SamplingParams(max_new_tokens=8))
    for turn, n_msg in enumerate((24, 8, 8)):   # long opener seeds blocks
        out = ses.send(rng.integers(0, cfg.vocab_size, size=n_msg))
        print(f"[session] turn {turn + 1}: prompt={len(out.prompt_token_ids)}"
              f" cached={out.cached_tokens} prefilled={out.prefill_tokens}")
    print(f"[session] prefix-cache stats: {llm.core.prefix_stats()}")


if __name__ == "__main__":
    main()
