"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains *reduced* configs end-to-end (the full
configs are exercised allocation-free by the dry-run). On a TPU fleet the
same driver runs the full config: the mesh comes from ``jax.device_count()``
(elastic), shardings from the logical-axis rules, and the XLA flags below
enable the latency-hiding scheduler for compute/comm overlap.

TPU launch (documented for real runs; harmless here):
  LIBTPU_INIT_ARGS="--xla_tpu_enable_async_collective_permute=true"
  XLA_FLAGS="--xla_tpu_enable_latency_hiding_scheduler=true \
             --xla_tpu_megacore_fusion_allow_ags=true"
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.base import get_config, reduced
from repro.data.pipeline import DataConfig
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="chai-llama-7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--full", action="store_true",
                    help="train the full (not reduced) config — TPU fleets")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="model-parallel width for the elastic mesh")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    cfg = cfg.replace(dtype="float32") if not args.full else cfg

    mesh = None
    if jax.device_count() > 1:
        from repro.launch.mesh import elastic_mesh
        mesh = elastic_mesh(model_parallel=args.mesh_model)
        print(f"[train] mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                         ckpt_dir=args.ckpt_dir, n_micro=args.n_micro)
    trainer = Trainer(cfg, data_cfg, tcfg, mesh=mesh)
    state, metrics = trainer.run()
    print(f"[train] done: loss={float(metrics['loss']):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
