"""Paged KV cache: allocator behaviour + paged-vs-dense engine parity.

The paged layout must be a pure layout change: greedy tokens identical to
the dense unified layout (and the cohort scheduler) for every arch/flag
combination, while the page allocator realizes CHAI's memory saving —
dense K pages return to the pool at compaction, admission is page-budget
gated, and nothing leaks across slot churn.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.core import cache as chai_cache
from repro.core.clustering import chai_widths
from repro.models import transformer as tfm
from repro.serving.engine import EngineConfig, ServingEngine

MHA_ARCH = "chai-llama-7b"
GQA_ARCH = "nemotron-4-15b"


def _cfg(arch, **chai_kw):
    cfg = reduced(get_config(arch), n_layers=2, d_model=32, d_ff=64,
                  vocab=64).replace(dtype="float32")
    return cfg.with_chai(enabled=True, warmup_tokens=3, **chai_kw)


def _run(cfg, submissions, *, scheduler="continuous", kv_layout="paged",
         use_chai=True, slots=2, max_seq=64, **ecfg_kw):
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=slots, max_seq=max_seq,
                                     scheduler=scheduler,
                                     kv_layout=kv_layout,
                                     use_chai=use_chai, page_size=16,
                                     **ecfg_kw))
    for i, (prompt, max_new) in enumerate(submissions):
        eng.submit(prompt, max_new_tokens=max_new, uid=i)
    done = eng.run()
    assert len(done) == len(submissions)
    return {r.uid: r for r in done}, eng


def _submissions(cfg, lens=(12, 5, 9, 7), prompt_len=8, seed=0):
    rng = np.random.default_rng(seed)
    return [(rng.integers(0, cfg.vocab_size, size=prompt_len), m)
            for m in lens]


# ---------------------------------------------------------- PagePool -------
def test_page_pool_alloc_free_exhaustion():
    pool = chai_cache.PagePool(8, 16)       # 7 usable (page 0 = null)
    assert pool.capacity == 7 and pool.free_pages == 7
    a = pool.alloc(3)
    b = pool.alloc(4)
    assert pool.free_pages == 0 and pool.pages_in_use == 7
    assert chai_cache.NULL_PAGE not in a + b
    assert len(set(a + b)) == 7             # all distinct
    with pytest.raises(MemoryError):
        pool.alloc(1)
    pool.free(a)
    assert pool.free_pages == 3
    c = pool.alloc(3)                       # freed pages are reusable
    assert sorted(c) == sorted(a)
    pool.free(b)
    pool.free(c)
    assert pool.pages_in_use == 0


def test_page_pool_guards():
    pool = chai_cache.PagePool(4, 16)
    pages = pool.alloc(2)
    pool.free(pages[:1])
    with pytest.raises(AssertionError):     # double free
        pool.free(pages[:1])
    with pytest.raises(AssertionError):     # null page is never freeable
        pool.free([chai_cache.NULL_PAGE])


def test_pages_needed_ceil():
    assert chai_cache.pages_needed(1, 16) == 1
    assert chai_cache.pages_needed(16, 16) == 1
    assert chai_cache.pages_needed(17, 16) == 2
    assert chai_cache.pages_needed(64, 16) == 4


# ------------------------------------------------- structs + accounting ----
def test_paged_state_structs_layout():
    """Paged structs: dense rectangles replaced by pool + block tables;
    clustered pool only for MHA+CHAI; scale pools only under int8."""
    cfg = _cfg(MHA_ARCH)
    shapes, _ = chai_cache.paged_state_structs(cfg, 2, 64, page_size=16,
                                               dense_pages=9, chai_pages=5)
    assert "kg" not in shapes and "vg" not in shapes
    assert shapes["kvp"].shape == (2, 9, cfg.n_kv_heads, 16, cfg.head_dim)
    k_max, _ = chai_widths(cfg)
    assert shapes["cp"].shape == (2, 5, k_max, 16, cfg.head_dim)
    assert shapes["bt_kg"].shape == shapes["bt_vg"].shape == (2, 4)
    assert shapes["bt_kc"].shape == (2, 4)
    assert "bt_vc" not in shapes            # share_values off
    assert "kvp_scale" not in shapes        # fp32 cache

    gqa = _cfg(GQA_ARCH)
    shapes, _ = chai_cache.paged_state_structs(gqa, 2, 64, page_size=16,
                                               dense_pages=9)
    assert "cp" not in shapes and "bt_kc" not in shapes
    assert "chai_scores" in shapes          # compute-only saving remains

    i8 = _cfg(MHA_ARCH).replace(kv_cache_dtype="int8")
    shapes, _ = chai_cache.paged_state_structs(i8, 2, 64, page_size=16,
                                               dense_pages=9, chai_pages=5)
    assert shapes["kvp"].dtype == jnp.int8
    assert shapes["kvp_scale"].shape == (2, 9, i8.n_kv_heads, 16)
    assert shapes["cp_scale"].shape == (2, 5, k_max, 16)


def test_paged_kv_bytes_accounting():
    """Allocated bytes = pages-in-use x page bytes; a steady CHAI slot
    (k_max clustered rows, dense K freed) costs less than its dense
    residency (KV rows for K AND V)."""
    cfg = _cfg(MHA_ARCH)
    dense_pb = chai_cache.paged_page_bytes(cfg, 16, kind="dense")
    chai_pb = chai_cache.paged_page_bytes(cfg, 16, kind="chai")
    k_max, _ = chai_widths(cfg)
    assert dense_pb == 2 * cfg.n_kv_heads * 16 * cfg.head_dim * 4
    assert chai_pb == 2 * k_max * 16 * cfg.head_dim * 4
    assert chai_pb < dense_pb               # k_max < n_heads
    assert chai_cache.paged_kv_bytes(cfg, 16, 3, 2) == \
        3 * dense_pb + 2 * chai_pb
    # WARMUP residency (K+V dense + reserved-nothing) vs STEADY residency
    # (V dense + K clustered): steady strictly cheaper.
    warm = chai_cache.paged_kv_bytes(cfg, 16, 2, 0)     # K + V pages
    steady = chai_cache.paged_kv_bytes(cfg, 16, 1, 1)   # V + clustered K
    assert steady < warm


# ------------------------------------------------------------ parity -------
@pytest.mark.slow
@pytest.mark.parametrize("arch", [MHA_ARCH, GQA_ARCH])
def test_paged_greedy_parity(arch):
    """Token-for-token parity: paged continuous == dense continuous ==
    cohort, through PREFILL/WARMUP/CLUSTER/STEADY phase mixes."""
    cfg = _cfg(arch)
    subs = _submissions(cfg, lens=(12, 5, 9, 12, 7))
    paged, engp = _run(cfg, subs, kv_layout="paged")
    dense, _ = _run(cfg, subs, kv_layout="dense")
    cohort, _ = _run(cfg, subs, scheduler="cohort")
    for uid in dense:
        assert paged[uid].generated == dense[uid].generated, uid
        assert paged[uid].generated == cohort[uid].generated, uid
    # every page went home
    assert engp.dense_pool.pages_in_use == 0
    if engp.chai_pool is not None:
        assert engp.chai_pool.pages_in_use == 0


@pytest.mark.slow
@pytest.mark.parametrize("share_values", [False, True])
def test_paged_parity_int8_and_shared_values(share_values):
    """The int8 scale pools and the clustered-V pages reproduce the dense
    layout's numerics exactly."""
    cfg = _cfg(MHA_ARCH, share_values=share_values).replace(
        kv_cache_dtype="int8")
    subs = _submissions(cfg, lens=(10, 6, 8))
    paged, engp = _run(cfg, subs, kv_layout="paged")
    dense, _ = _run(cfg, subs, kv_layout="dense")
    for uid in dense:
        assert paged[uid].generated == dense[uid].generated, uid
    assert engp.dense_pool.pages_in_use == 0
    assert engp.chai_pool.pages_in_use == 0


@pytest.mark.slow
def test_paged_parity_int8_gqa_dense_layout_carries_scales():
    """Regression for the legacy dense-GQA int8 corner: the dense layout
    now gathers real per-row scales exactly like the paged path, so
    paged-vs-dense greedy parity holds for GQA int8 too (it could not
    before — dense stored reinterpreted codes with no scales)."""
    cfg = _cfg(GQA_ARCH).replace(kv_cache_dtype="int8")
    subs = _submissions(cfg, lens=(10, 6, 8))
    paged, engp = _run(cfg, subs, kv_layout="paged")
    dense, _ = _run(cfg, subs, kv_layout="dense")
    cohort, _ = _run(cfg, subs, scheduler="cohort")
    for uid in dense:
        assert paged[uid].generated == dense[uid].generated, uid
        assert paged[uid].generated == cohort[uid].generated, uid
    assert engp.dense_pool.pages_in_use == 0


# ------------------------------------------------- allocator behaviour -----
@pytest.mark.slow
def test_exhausted_pool_queues_admission_then_reuses_pages():
    """A pool sized for ONE request serializes admission: later requests
    wait in the queue (page-budget gate), are admitted as pages free at
    retire, and all complete with tokens identical to an unconstrained
    run. After N churn cycles, zero pages leak."""
    cfg = _cfg(MHA_ARCH)
    subs = _submissions(cfg, lens=(8, 8, 8, 8, 8), prompt_len=8)
    need = chai_cache.pages_needed(8 + 8, 16)
    tight, engt = _run(cfg, subs, kv_layout="paged",
                       num_pages=2 * need + 1, num_chai_pages=need + 1)
    roomy, engr = _run(cfg, subs, kv_layout="paged")
    for uid in roomy:
        assert tight[uid].generated == roomy[uid].generated, uid
    # page-budget admission actually serialized the tight run: with pages
    # for only one in-flight request, later requests started strictly
    # after earlier ones retired, despite 2 batch slots being free.
    admits = sorted((tight[u].admit_step, tight[u].retire_step)
                    for u in tight)
    for (a1, _), (_, r0) in zip(admits[1:], admits[:-1]):
        assert a1 >= r0
    # the roomy run interleaved (continuous batching baseline behaviour)
    assert engr.steps_executed < engt.steps_executed
    # churn left nothing behind
    assert engt.dense_pool.pages_in_use == 0
    assert engt.chai_pool.pages_in_use == 0


@pytest.mark.slow
def test_oversized_request_raises_memory_error():
    cfg = _cfg(MHA_ARCH)
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params,
                        EngineConfig(batch_slots=2, max_seq=64,
                                     kv_layout="paged", page_size=16,
                                     num_pages=3))
    # beyond the KV capacity entirely: rejected at submit, any layout
    with pytest.raises(ValueError):
        eng.submit(np.zeros(40, np.int32), max_new_tokens=40)
    # fits max_seq but not this (deliberately tiny) pool: page-budget
    # admission raises once the engine is idle and it still cannot fit
    eng.submit(np.zeros(40, np.int32), max_new_tokens=20)
    with pytest.raises(MemoryError):
        eng.run()


# ------------------------------------------------ fused decode kernel ------
@pytest.mark.slow
@pytest.mark.parametrize("arch,chai_kw,cfg_kw", [
    (MHA_ARCH, {}, {}),
    (MHA_ARCH, {"share_values": True}, {"kv_cache_dtype": "int8"}),
    (GQA_ARCH, {}, {}),
])
def test_fused_decode_greedy_parity_with_jnp_reference(monkeypatch, arch,
                                                       chai_kw, cfg_kw):
    """End-to-end acceptance: the fused one-launch decode produces
    token-for-token greedy parity with the pre-fusion jnp math across
    phase mixes, layouts, int8 and share_values."""
    from repro.core import chai_attention as chai_core
    cfg = _cfg(arch, **chai_kw).replace(**cfg_kw)
    subs = _submissions(cfg, lens=(10, 6, 8))
    fused_p, _ = _run(cfg, subs, kv_layout="paged")
    fused_d, _ = _run(cfg, subs, kv_layout="dense")
    monkeypatch.setattr(chai_core, "USE_FUSED_DECODE", False)
    reference, _ = _run(cfg, subs, kv_layout="paged")
    for uid in reference:
        assert fused_p[uid].generated == reference[uid].generated, uid
        assert fused_d[uid].generated == reference[uid].generated, uid


# ------------------------------------------- the memory win, realized ------
@pytest.mark.slow
def test_steady_state_paged_chai_below_dense_mha():
    """The acceptance criterion: with kv_layout='paged', the allocator's
    steady-state CHAI footprint is BELOW the dense-MHA rectangle the
    continuous engine previously kept resident — and the trajectory
    shows the drop at compaction."""
    cfg = _cfg(MHA_ARCH)
    subs = _submissions(cfg, lens=(24, 24), prompt_len=8)
    _, eng = _run(cfg, subs, kv_layout="paged", max_seq=64)
    hist = eng.kv_bytes_history
    assert hist, "paged engine records its allocated-bytes trajectory"
    dense_mha = chai_cache.unified_kv_bytes(cfg, 2, 64, chai=False)
    warm_peak = max(h["kv_bytes"] for h in hist)
    # steady state: every slot past CLUSTER (dense K pages freed)
    steady = [h for h in hist if h["step"] > cfg.chai.warmup_tokens + 1]
    assert steady, hist
    steady_bytes = steady[-1]["kv_bytes"]
    assert steady_bytes < warm_peak          # compaction freed pages
    assert steady_bytes < dense_mha          # CHAI saving, allocator-level
    # and the dense unified layout cannot say the same
    assert chai_cache.unified_kv_bytes(cfg, 2, 64, chai=True) > dense_mha
