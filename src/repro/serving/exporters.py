"""Export formats for serving telemetry: Prometheus text, Chrome trace,
JSONL event logs — plus the matching loaders used by tests and benches.

Everything here is pure data-to-text (and back); the live sinks are in
``serving/telemetry.py``.  No third-party dependencies.
"""
from __future__ import annotations

import json
import math
from typing import Any, Dict, Iterable, List, Union

# ---------------------------------------------------------------------------
# Prometheus text exposition format (version 0.0.4)
# ---------------------------------------------------------------------------

PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def _escape_label(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\"", "\\\"").replace("\n", "\\n")


def _fmt_labels(labels: Dict[str, str], extra: Dict[str, str] = None) -> str:
    items = dict(labels or {})
    if extra:
        items.update(extra)
    if not items:
        return ""
    inner = ",".join(f'{k}="{_escape_label(str(v))}"'
                     for k, v in sorted(items.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if isinstance(v, float) and math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


def to_prometheus(snapshot: Dict[str, Any]) -> str:
    """Render a ``MetricsRegistry.snapshot()`` as Prometheus text."""
    lines: List[str] = []
    for name, m in snapshot.get("counters", {}).items():
        if m.get("help"):
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} counter")
        for s in m["series"]:
            lines.append(f"{name}{_fmt_labels(s['labels'])} "
                         f"{_fmt_value(s['value'])}")
    for name, m in snapshot.get("gauges", {}).items():
        if m.get("help"):
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} gauge")
        for s in m["series"]:
            lines.append(f"{name}{_fmt_labels(s['labels'])} "
                         f"{_fmt_value(s['value'])}")
    for name, m in snapshot.get("histograms", {}).items():
        if m.get("help"):
            lines.append(f"# HELP {name} {m['help']}")
        lines.append(f"# TYPE {name} histogram")
        bounds = list(m["buckets"]) + [math.inf]
        for s in m["series"]:
            cum = 0
            for ub, c in zip(bounds, s["counts"]):
                cum += c
                le = "+Inf" if math.isinf(ub) else _fmt_value(ub)
                lines.append(
                    f"{name}_bucket{_fmt_labels(s['labels'], {'le': le})} "
                    f"{cum}")
            lines.append(f"{name}_sum{_fmt_labels(s['labels'])} "
                         f"{_fmt_value(s['sum'])}")
            lines.append(f"{name}_count{_fmt_labels(s['labels'])} "
                         f"{s['count']}")
    return "\n".join(lines) + "\n"


def parse_prometheus(text: str) -> Dict[str, Any]:
    """Minimal Prometheus text parser (for selftests and claim checks).

    Returns ``{"types": {name: type}, "samples": [(name, labels, value)]}``
    and raises ``ValueError`` on lines that are not valid exposition
    format.
    """
    types: Dict[str, str] = {}
    samples: List[tuple] = []
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = raw.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 4 and parts[1] == "TYPE":
                if parts[3] not in ("counter", "gauge", "histogram",
                                    "summary", "untyped"):
                    raise ValueError(f"line {lineno}: bad TYPE {parts[3]}")
                types[parts[2]] = parts[3]
            elif len(parts) >= 2 and parts[1] in ("HELP", "TYPE"):
                pass
            else:
                raise ValueError(f"line {lineno}: bad comment {line!r}")
            continue
        # sample: name{labels} value
        if "{" in line:
            name, rest = line.split("{", 1)
            lab_str, val_str = rest.rsplit("}", 1)
            labels = {}
            for item in _split_labels(lab_str):
                if not item:
                    continue
                k, v = item.split("=", 1)
                if not (v.startswith('"') and v.endswith('"')):
                    raise ValueError(f"line {lineno}: unquoted label {item!r}")
                labels[k.strip()] = (v[1:-1].replace('\\"', '"')
                                     .replace("\\n", "\n")
                                     .replace("\\\\", "\\"))
        else:
            parts = line.split()
            if len(parts) != 2:
                raise ValueError(f"line {lineno}: bad sample {line!r}")
            name, val_str, labels = parts[0], parts[1], {}
        name = name.strip()
        val_str = val_str.strip().split()[0]
        if val_str == "+Inf":
            value = math.inf
        elif val_str == "-Inf":
            value = -math.inf
        else:
            value = float(val_str)
        if not name or not all(c.isalnum() or c in "_:" for c in name):
            raise ValueError(f"line {lineno}: bad metric name {name!r}")
        samples.append((name, labels, value))
    return {"types": types, "samples": samples}


def _split_labels(s: str) -> List[str]:
    out, cur, in_q, esc = [], [], False, False
    for ch in s:
        if esc:
            cur.append(ch)
            esc = False
        elif ch == "\\":
            cur.append(ch)
            esc = True
        elif ch == '"':
            cur.append(ch)
            in_q = not in_q
        elif ch == "," and not in_q:
            out.append("".join(cur).strip())
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur).strip())
    return out


# ---------------------------------------------------------------------------
# Chrome trace (chrome://tracing / Perfetto "trace event" JSON)
# ---------------------------------------------------------------------------

def to_chrome_trace(spans: Iterable[Dict[str, Any]],
                    pid: int = 0) -> Dict[str, Any]:
    """Render telemetry spans as a Chrome trace-event JSON object.

    Span times are perf_counter seconds; Chrome wants microseconds.
    Every span becomes one complete ("ph": "X") event on pid/tid 0 with
    the step number and any args attached.
    """
    events = []
    for sp in spans:
        args = dict(sp.get("args") or {})
        if sp.get("step", -1) >= 0:
            args["step"] = sp["step"]
        if sp.get("error"):
            args["error"] = True
        events.append({
            "name": sp["name"], "cat": "engine", "ph": "X",
            "ts": sp["t0"] * 1e6,
            "dur": max(0.0, (sp["t1"] - sp["t0"]) * 1e6),
            "pid": pid, "tid": 0, "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def from_chrome_trace(obj: Union[str, Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Load + validate a Chrome trace; returns the span-like event list.

    Accepts the JSON text or the already-decoded object and raises
    ``ValueError`` if required trace-event keys are missing.
    """
    if isinstance(obj, (str, bytes)):
        obj = json.loads(obj)
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a Chrome trace: missing traceEvents")
    out = []
    for i, ev in enumerate(obj["traceEvents"]):
        for key in ("name", "ph", "ts", "pid", "tid"):
            if key not in ev:
                raise ValueError(f"traceEvents[{i}]: missing {key!r}")
        if ev["ph"] == "X" and "dur" not in ev:
            raise ValueError(f"traceEvents[{i}]: complete event missing dur")
        out.append(ev)
    return out


# ---------------------------------------------------------------------------
# JSONL event logs (request lifecycle events, one JSON object per line)
# ---------------------------------------------------------------------------

def events_jsonl(events: Iterable[Dict[str, Any]]) -> str:
    """Serialize lifecycle events as JSONL, globally ordered by time."""
    evs = sorted(events, key=lambda e: e.get("t", 0.0))
    return "\n".join(json.dumps(e, sort_keys=True) for e in evs) + (
        "\n" if evs else "")


def read_jsonl(text: str) -> List[Dict[str, Any]]:
    out = []
    for line in text.splitlines():
        line = line.strip()
        if line:
            out.append(json.loads(line))
    return out
