"""Prefill + N decode steps must equal one full forward pass.

The strongest end-to-end invariant in the system: caches (dense KV, ring
KV, RG-LRU hidden state, RWKV wkv state) and the decode-path math must
reproduce the train-path logits exactly (float32, same MoE impl).
Covers dense-global, GQA, sliding-window, MoE, hybrid-recurrent and SSM
families.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import get_config, reduced
from repro.launch import steps as steps_mod
from repro.models import transformer as tfm

# window=8 < s exercises the ring buffer on local-attention archs.
PARITY_ARCHS = ["musicgen-large", "nemotron-4-15b", "gemma2-9b",
                "deepseek-moe-16b", "recurrentgemma-9b", "rwkv6-1.6b"]


@pytest.mark.parametrize("arch", ["gemma2-9b", "nemotron-4-15b"])
def test_bucketed_prefill_matches_exact_prefill(arch, rng):
    """The engine's power-of-two prompt bucketing (right-pad +
    ``valid_len``) must reproduce the exact-length prefill bit-for-bit
    observable: same last-token logits and same decode-step logits.
    gemma2 covers LOCAL ring caches with bucket > window > true_len gap
    (the ring must hold the last ``window`` REAL positions, not the
    padded tail); nemotron covers plain global GQA."""
    cfg = reduced(get_config(arch), window=8).replace(dtype="float32")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    b, t, bucket, s, n_dec = 2, 10, 32, 64, 3
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, t)), jnp.int32)
    padded = jnp.zeros((b, bucket), jnp.int32).at[:, :t].set(toks)

    state_ref = tfm.init_decode_state(cfg, b, s)
    logits_ref, state_ref, _ = tfm.forward_fullseq(
        params, cfg, toks, state=state_ref, logits_slice="last",
        moe_impl="ragged")
    state_bkt = tfm.init_decode_state(cfg, b, s)
    logits_bkt, state_bkt, _ = tfm.forward_fullseq(
        params, cfg, padded, state=state_bkt, logits_slice="last",
        moe_impl="ragged", valid_len=jnp.int32(t))
    np.testing.assert_allclose(np.asarray(logits_bkt),
                               np.asarray(logits_ref), rtol=2e-4,
                               atol=2e-4)
    assert (np.asarray(state_bkt["pos"]) == t).all()
    nxt = jnp.asarray(rng.integers(0, cfg.vocab_size, (n_dec, b)),
                      jnp.int32)
    for i in range(n_dec):
        l_ref, state_ref = tfm.decode_step(params, cfg, nxt[i], state_ref,
                                           moe_impl="ragged")
        l_bkt, state_bkt = tfm.decode_step(params, cfg, nxt[i], state_bkt,
                                           moe_impl="ragged")
        np.testing.assert_allclose(np.asarray(l_bkt), np.asarray(l_ref),
                                   rtol=3e-4, atol=3e-4,
                                   err_msg=f"{arch} decode step {i}")


@pytest.mark.parametrize("arch", PARITY_ARCHS)
def test_prefill_decode_matches_fullseq(arch, rng):
    cfg = reduced(get_config(arch), window=8).replace(dtype="float32")
    params = tfm.init_params(cfg, jax.random.PRNGKey(0))
    b, t0, n_dec, s = 2, 8, 4, 32
    total = t0 + n_dec
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, total)),
                       jnp.int32)

    # reference: single full forward over all tokens (exact dropless MoE)
    logits_full, _, _ = tfm.forward_fullseq(params, cfg, toks,
                                            moe_impl="ragged")

    # prefill on the first t0, then decode token-by-token
    state = tfm.init_decode_state(cfg, b, s)
    logits_pre, state, _ = tfm.forward_fullseq(
        params, cfg, toks[:, :t0], state=state, moe_impl="ragged")
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, :t0]),
                               rtol=2e-4, atol=2e-4)
    for i in range(n_dec):
        logits_i, state = tfm.decode_step(params, cfg, toks[:, t0 + i],
                                          state, moe_impl="ragged")
        np.testing.assert_allclose(
            np.asarray(logits_i), np.asarray(logits_full[:, t0 + i]),
            rtol=3e-4, atol=3e-4, err_msg=f"{arch} decode step {i}")
