"""Shared fixtures. NOTE: no XLA device-count flag here on purpose —
smoke tests and benches must see the real single CPU device; only
launch/dryrun.py (its own process) forces 512 placeholder devices."""
import numpy as np
import pytest

import jax


@pytest.fixture(scope="session", autouse=True)
def _x64_off():
    jax.config.update("jax_enable_x64", False)


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _page_leak_gate(request):
    """Universal serving-tier leak gate: every ``EngineCore`` built
    during a test is audited afterwards — pool conservation (device AND
    host/compressed tier pools) always, and (for cores left IDLE) zero
    leaked page references / dangling prefix-cache locks / orphaned
    host-tier pages. Replaces the old ad-hoc per-test counter checks.
    Opt out with ``@pytest.mark.no_leak_gate`` (tests that corrupt
    engine state on purpose)."""
    from repro.serving.engine import EngineCore

    cores = []
    orig = EngineCore.__init__

    def patched(self, *args, **kw):
        orig(self, *args, **kw)
        cores.append(self)

    EngineCore.__init__ = patched
    try:
        yield
    finally:
        EngineCore.__init__ = orig
    if request.node.get_closest_marker("no_leak_gate"):
        return
    from repro.serving import invariants
    problems = []
    for core in cores:
        if getattr(core, "_slot_req", None) is None or not core.paged:
            continue        # cohort / dense layouts: nothing paged
        for v in invariants.audit_leaks(core):
            problems.append(v)
    assert not problems, (
        "page leak gate: engine(s) left damaged state behind:\n  "
        + "\n  ".join(problems))


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers",
        "no_leak_gate: skip the autouse EngineCore page-leak audit")
