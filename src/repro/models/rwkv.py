"""RWKV-6 "Finch" block: data-dependent-decay linear attention + channel mix.

Full-sequence path is **chunkwise-parallel** (flash-linear-attention style):
sequential ``lax.scan`` over chunks carrying the (B, H, dk, dv) state, with
MXU-friendly matmuls inside each chunk. Intra-chunk relative decays use the
factored form R~ = r * exp(logW_{i-1}), K~ = k * exp(-logW_j); per-step
log-decay is clamped to >= -LOG_CLAMP so the factored exponentials stay in
fp32 range for the chunk length used (documented in DESIGN.md §3).
Decode is the plain one-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.compat import opt_barrier

CHUNK = 32
LOG_CLAMP = 1.5          # per-step |log w| cap; CHUNK*LOG_CLAMP = 48 < 88


def _token_shift_full(x, last):
    """x: (B, T, d); last: (B, d) previous token (state) -> shifted x."""
    prev = jnp.concatenate([last[:, None], x[:, :-1]], axis=1)
    return prev


def _decays(xw, p, cfg):
    """Data-dependent per-channel log decay, clamped. xw: (..., d)."""
    lora = jnp.einsum("...d,dr->...r", xw, p["w_decay_a"])
    lora = jnp.einsum("...r,rd->...d", jnp.tanh(lora), p["w_decay_b"])
    logw = -jnp.exp(jnp.clip(p["decay_base"] + lora, -8.0, 1.0))
    return jnp.maximum(logw.astype(jnp.float32), -LOG_CLAMP)


def _pin_replicated_d(t):
    """Keep (B, T, d) activations replicated on d (batch stays sharded).

    Without this GSPMD computes the lerp d-sharded and ALL-GATHERS it in
    f32 before each projection matmul — 6 x 512 MB/layer of pure wire
    waste on the prefill cells (EXPERIMENTS.md §Perf cell 2). Only active
    under a sharding ctx (production meshes); no-op on CPU tests.
    """
    from repro.sharding.context import current_ctx
    ctx = current_ctx()
    if ctx is None or t.ndim != 3:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P
    ba = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(ctx.mesh, P(ba, None, None)))


def _project(x, xs, p, cfg):
    """Lerped projections. x: (..., d); xs: shifted x."""
    mu = p["mu"]  # (5, d): r, k, v, w, g

    def lerp(i):
        # NOTE: a with_sharding_constraint pin here was measured WORSE
        # (+10% collective bytes — it adds a resharding instead of
        # changing the producer's layout; EXPERIMENTS.md §Perf cell 2 it1)
        return x + (xs - x) * mu[i]

    r = jnp.einsum("...d,de->...e", lerp(0), p["w_r"])
    k = jnp.einsum("...d,de->...e", lerp(1), p["w_k"])
    v = jnp.einsum("...d,de->...e", lerp(2), p["w_v"])
    logw = _decays(lerp(3), p, cfg)
    g = jax.nn.silu(jnp.einsum("...d,de->...e", lerp(4), p["w_g"]))
    return r, k, v, logw, g


def _heads(x, n_heads, hd):
    return x.reshape(*x.shape[:-1], n_heads, hd)


def _group_norm(o, scale, eps):
    """Per-head group norm on (..., H, hd)."""
    mean = o.mean(axis=-1, keepdims=True)
    var = o.var(axis=-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + eps)
    return o * (1.0 + scale)


def rwkv_time_mix_fullseq(x, p, cfg, state):
    """x: (B, T, d); state: dict(shift=(B, d), wkv=(B, H, dk, dv))."""
    bsz, t, d = x.shape
    nh, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    xs = _token_shift_full(x, state["shift"])
    r, k, v, logw, g = _project(x, xs, p, cfg)
    r, k, v = (_heads(a, nh, hd).astype(jnp.float32) for a in (r, k, v))
    logw = _heads(logw, nh, hd)                              # (B, T, H, hd)
    u = p["u"].astype(jnp.float32)                           # (H, hd)

    c = min(CHUNK, t)
    assert t % c == 0, (t, c)
    nc = t // c

    def chunked(a):  # (B, T, H, X) -> (nc, B, H, c, X)
        return a.reshape(bsz, nc, c, nh, -1).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, wc = map(chunked, (r, k, v, logw))

    def step(s, xs_):
        r_i, k_i, v_i, lw_i = xs_                            # (B, H, c, hd)
        cum = jnp.cumsum(lw_i, axis=2)                       # logW_i (inclusive)
        cum_prev = cum - lw_i                                # logW_{i-1}
        r_t = r_i * jnp.exp(cum_prev)
        k_t = k_i * jnp.exp(-cum)
        att = jnp.einsum("bhid,bhjd->bhij", r_t, k_t)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        att = jnp.where(mask, att, 0.0)
        bonus = jnp.einsum("bhid,bhid->bhi", r_i * u[None, :, None, :], k_i)
        o = jnp.einsum("bhij,bhjv->bhiv", att, v_i)          # intra
        o += jnp.einsum("bhid,bhdv->bhiv", r_t, s)           # cross-chunk
        o += bonus[..., None] * v_i                          # current token
        decay_all = jnp.exp(cum[:, :, -1:, :])               # exp(logW_c)
        k_rem = k_i * jnp.exp(cum[:, :, -1:, :] - cum)       # W_c / W_j
        s_new = s * jnp.swapaxes(decay_all, -1, -2) \
            + jnp.einsum("bhjd,bhjv->bhdv", k_rem, v_i)
        return s_new, o

    # state stores S with shape (B, H, dk, dv); decay applies on dk axis.
    s0 = state["wkv"].astype(jnp.float32)
    s_fin, o = jax.lax.scan(step, s0, (rc, kc, vc, wc))
    o = o.transpose(1, 0, 3, 2, 4).reshape(bsz, t, nh, hd)   # (B, T, H, hd)
    o = _group_norm(o, p["ln_x"].reshape(nh, hd), cfg.norm_eps)
    o = (o.reshape(bsz, t, d) * g.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("btd,de->bte", o, p["w_o"])
    # barrier: down-proj output must all-reduce in bf16; XLA otherwise
    # hoists the residual/norm f32 convert before the AR (2x wire bytes).
    y = opt_barrier(y)
    return y, {"shift": x[:, -1], "wkv": s_fin.astype(x.dtype)}


def rwkv_time_mix_decode(x, p, cfg, state):
    """x: (B, d); one-step recurrence."""
    bsz, d = x.shape
    nh, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    xs = state["shift"]
    r, k, v, logw, g = _project(x, xs, p, cfg)
    r, k, v = (_heads(a, nh, hd).astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(_heads(logw, nh, hd))                        # (B, H, hd)
    u = p["u"].astype(jnp.float32)
    s = state["wkv"].astype(jnp.float32)                     # (B, H, dk, dv)
    kv = jnp.einsum("bhd,bhv->bhdv", k, v)
    o = jnp.einsum("bhd,bhdv->bhv", r, s + u[None, :, :, None] * kv)
    s_new = s * w[..., None] + kv
    o = _group_norm(o, p["ln_x"].reshape(nh, hd), cfg.norm_eps)
    o = (o.reshape(bsz, d) * g.astype(jnp.float32)).astype(x.dtype)
    y = jnp.einsum("bd,de->be", o, p["w_o"])
    return y, {"shift": x, "wkv": s_new.astype(x.dtype)}


def rwkv_channel_mix_fullseq(x, p, last):
    xs = _token_shift_full(x, last)
    mu = p["cmu"]
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(jnp.einsum("...d,df->...f", xk, p["c_k"])))
    kv = jnp.einsum("...f,fd->...d", k, p["c_v"])
    kv = opt_barrier(kv)                      # bf16 AR (see time-mix)
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xr, p["c_r"]))
    return r * kv, x[:, -1]


def rwkv_channel_mix_decode(x, p, last):
    mu = p["cmu"]
    xk = x + (last - x) * mu[0]
    xr = x + (last - x) * mu[1]
    k = jnp.square(jax.nn.relu(jnp.einsum("bd,df->bf", xk, p["c_k"])))
    kv = jnp.einsum("bf,fd->bd", k, p["c_v"])
    r = jax.nn.sigmoid(jnp.einsum("bd,de->be", xr, p["c_r"]))
    return r * kv, x
