from repro.serving.api import LLM, RequestOutput, Session  # noqa: F401
from repro.serving.engine import (EngineConfig, EngineCore,  # noqa: F401
                                  Request, ServingEngine, StepOutput)
from repro.serving.faults import (CapacityError,  # noqa: F401
                                  EngineFault, FaultInjector, FaultSpec,
                                  QuarantineError, RequestError,
                                  SnapshotRestoreError, ValidationError)
from repro.serving.prefix_cache import ChaiSnapshot, PrefixCache  # noqa: F401
from repro.serving.sampling import SamplingParams  # noqa: F401
