"""Gemma-3 4B [hf:google/gemma-3]: 5:1 local:global, 128k context."""
from repro.configs.base import (ModelConfig, CHAIConfig, register,
                                ATTN_LOCAL, ATTN_GLOBAL)

# 5 local : 1 global repeating; 34 layers = 5 full patterns + 4 local.
_LAYERS = tuple(ATTN_GLOBAL if (i % 6) == 5 else ATTN_LOCAL for i in range(34))

CONFIG = register(ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab_size=262144,
    layer_types=_LAYERS,
    window_size=1024,
    activation="gelu",
    qk_norm=True,
    rope_theta=1000000.0,        # long-context rope base
    chai=CHAIConfig(enabled=True),
))
